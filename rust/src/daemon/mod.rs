//! Daemon mode and the recordable, replayable trace format (DESIGN.md
//! Sec. 3g; ROADMAP "Daemon mode + recordable trace format").
//!
//! The serving stack is bit-deterministic on a virtual clock; what it
//! lacked was a production shape. This module adds one without
//! touching the determinism: a long-running TCP server
//! ([`listener::Daemon`]) speaks a length-prefixed JSON protocol
//! ([`protocol`]), stamps each accepted request's *real* arrival time
//! onto the virtual clock exactly once at admission
//! ([`session::DaemonSession`]), and appends every accepted event to a
//! versioned trace ([`trace::Trace`]). `graphagile replay trace.json`
//! then re-executes the recorded events through
//! [`Coordinator::admit`](crate::serve::Coordinator::admit) offline and
//! — because arrivals, seeds, and config are all in the trace —
//! reproduces the recorded [`Response`] stream and [`ServeStats`]
//! bit-for-bit. `--verify` turns that into a regression gate.
//!
//! A `daemon --tenants tenants.json` session serves under per-tenant
//! QoS (see [`crate::serve::qos`]): the installed
//! [`TenantConfig`](crate::serve::TenantConfig) is recorded in the
//! trace header, the trace stamps version 3, and replay re-installs
//! the config so QoS scheduling decisions reproduce bit-for-bit.
//!
//! Observability rides along without changing the trace format: the
//! `metrics` protocol op serves a Prometheus snapshot of the live
//! counters (read-only, never recorded — scraping cannot perturb
//! replay), `daemon --chrome-trace out.json` exports the session's
//! span stream ([`crate::obs`]) at shutdown, and [`replay_traced`]
//! regenerates that exact span stream offline from the trace alone.

#![warn(missing_docs)]

pub mod client;
pub mod listener;
pub mod protocol;
pub mod session;
pub mod trace;

pub use client::{drive, scripted_workload, Client};
pub use listener::Daemon;
pub use protocol::{read_frame, write_frame, ClientMsg, MAX_FRAME};
pub use session::DaemonSession;
pub use trace::{Trace, TraceConfig, TraceEvent, TRACE_VERSION};

use crate::serve::{Coordinator, Response, ServeStats};
use anyhow::{bail, Result};

/// Build the coordinator a trace describes and feed it the recorded
/// admissions. Admission order is the determinism contract — events
/// are *not* re-sorted. `traced` turns the span tracer on before any
/// admission so the replayed span stream covers the whole session.
fn replay_coordinator(trace: &Trace, traced: bool) -> Coordinator {
    let mut coord = Coordinator::fleet(trace.config.hw.clone(), trace.config.fleet);
    if let Some(p) = &trace.config.fault_plan {
        coord.set_fault_plan(p.clone());
    }
    if let Some(t) = &trace.config.tenants {
        coord.set_tenants(t.clone());
    }
    coord.set_tracing(traced);
    for e in &trace.events {
        match e {
            TraceEvent::Admit(rq) => {
                coord.admit(rq.clone());
            }
            // Stats/drain queries are coordinator no-ops; fault and
            // decision events are re-derived from the embedded plan,
            // so the recorded copies are timeline documentation here.
            TraceEvent::Stats { .. }
            | TraceEvent::Drain { .. }
            | TraceEvent::Fault(_)
            | TraceEvent::Decision(_) => {}
        }
    }
    coord
}

/// Re-execute a trace's admitted events in recorded order through a
/// coordinator built from the trace's own config (fault plan included).
pub fn replay(trace: &Trace) -> (Vec<Response>, ServeStats) {
    let coord = replay_coordinator(trace, false);
    let stats = coord.stats();
    (coord.responses, stats)
}

/// [`replay`] with the span tracer on: additionally returns the
/// session's Chrome trace-event JSON. The responses and stats are
/// byte-identical to an untraced replay — tracing only observes.
pub fn replay_traced(trace: &Trace) -> (Vec<Response>, ServeStats, String) {
    let coord = replay_coordinator(trace, true);
    let stats = coord.stats();
    let spans = coord.chrome_trace_json();
    (coord.responses, stats, spans)
}

/// Replay and diff against the trace's recorded outcomes. Returns the
/// list of divergences (empty = bit-identical). Errors on a trace that
/// has no recorded outcomes — verifying against nothing would be a
/// vacuous pass.
pub fn verify(trace: &Trace) -> Result<Vec<String>> {
    if trace.responses.is_empty() && trace.stats.is_none() {
        bail!(
            "trace has no recorded responses or stats to verify against \
             (events-only traces can be replayed, not verified)"
        );
    }
    let coord = replay_coordinator(trace, false);
    let stats = coord.stats();
    let responses = &coord.responses;
    let mut divergences = Vec::new();
    if responses.len() != trace.responses.len() {
        divergences.push(format!(
            "response count: recorded {} != replayed {}",
            trace.responses.len(),
            responses.len()
        ));
    }
    for (i, (rec, rep)) in trace.responses.iter().zip(responses).enumerate() {
        for d in rec.diff(rep) {
            divergences.push(format!("responses[{i}].{d}"));
        }
    }
    if let Some(rec) = &trace.stats {
        for d in rec.diff(&stats) {
            divergences.push(format!("stats.{d}"));
        }
    }
    // The recorded fault/decision streams must match what the replayed
    // plan re-derives — a lost or reordered event is a divergence even
    // when every response happens to agree.
    let rec_faults: Vec<_> = trace
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Fault(f) => Some(f.clone()),
            _ => None,
        })
        .collect();
    if rec_faults.as_slice() != coord.fault_log() {
        divergences.push(format!(
            "fault events: recorded {} diverge from the {} the plan replays to",
            rec_faults.len(),
            coord.fault_log().len()
        ));
    }
    let rec_decisions: Vec<_> = trace
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Decision(d) => Some(*d),
            _ => None,
        })
        .collect();
    if rec_decisions.as_slice() != coord.decision_log() {
        divergences.push(format!(
            "decision events: recorded {} diverge from the {} the plan replays to",
            rec_decisions.len(),
            coord.decision_log().len()
        ));
    }
    Ok(divergences)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::graph::dataset;
    use crate::ir::ZooModel;
    use crate::serve::{FleetConfig, Request};

    fn recorded_session() -> Trace {
        let mut s = DaemonSession::new(HwConfig::alveo_u250(), FleetConfig::default());
        let co = dataset("CO").unwrap();
        let pu = dataset("PU").unwrap();
        s.submit(Request::full(0, ZooModel::B2, co, 0.0)).unwrap();
        s.submit(Request::minibatch(1, ZooModel::B1, co, vec![5, 9], vec![8, 4], 3, 0.0))
            .unwrap();
        s.submit(Request::update(0, pu, 32, 8, 1, 11, 0.0)).unwrap();
        s.submit(Request::full(2, ZooModel::B7, pu, 0.0)).unwrap();
        s.drain();
        s.finalize()
    }

    #[test]
    fn replay_reproduces_a_recorded_session_bit_identically() {
        let trace = recorded_session();
        assert_eq!(verify(&trace).unwrap(), Vec::<String>::new());
        // Through a full encode/decode cycle too.
        let decoded = Trace::parse(&trace.encode()).unwrap();
        assert_eq!(verify(&decoded).unwrap(), Vec::<String>::new());
    }

    #[test]
    fn verify_names_an_injected_divergence() {
        let mut trace = recorded_session();
        trace.responses[1].latency += 1e-9;
        if let Some(s) = trace.stats.as_mut() {
            s.cache_hits += 1;
        }
        let div = verify(&trace).unwrap();
        assert!(div.iter().any(|d| d.starts_with("responses[1].latency:")), "{div:?}");
        assert!(div.iter().any(|d| d.starts_with("stats.cache_hits:")), "{div:?}");
    }

    #[test]
    fn faulty_recordings_verify_clean_and_catch_tampering() {
        use crate::serve::{CostModel, FaultEvent, FaultPlan};
        let costs = CostModel { deadline_s: f64::INFINITY, ..CostModel::default() };
        let fleet = FleetConfig { n_devices: 2, costs, ..FleetConfig::default() };
        let plan = FaultPlan {
            seed: 7,
            events: vec![FaultEvent::TransientStall { device: 0, at: 0.0, duration: 1e-6 }],
        };
        let mut s = DaemonSession::with_plan(HwConfig::alveo_u250(), fleet, Some(plan));
        let co = dataset("CO").unwrap();
        s.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        s.drain();
        let trace = s.finalize();
        assert_eq!(trace.version, 2);
        assert_eq!(verify(&trace).unwrap(), Vec::<String>::new());
        // Through a full encode/decode cycle too.
        let decoded = Trace::parse(&trace.encode()).unwrap();
        assert_eq!(verify(&decoded).unwrap(), Vec::<String>::new());
        // Dropping a recorded fault event is a named divergence.
        let mut tampered = trace;
        tampered.events.retain(|e| !matches!(e, TraceEvent::Fault(_)));
        let div = verify(&tampered).unwrap();
        assert!(div.iter().any(|d| d.starts_with("fault events:")), "{div:?}");
    }

    #[test]
    fn tenant_recordings_verify_clean_and_catch_tampering() {
        use crate::serve::{PriorityClass, Tenant, TenantConfig};
        let tenants = TenantConfig {
            tenants: vec![
                Tenant { id: 0, weight: 4.0, deadline_s: None, class: PriorityClass::Premium },
                Tenant {
                    id: 1,
                    weight: 1.0,
                    deadline_s: Some(1e-9),
                    class: PriorityClass::BestEffort,
                },
            ],
        };
        let fleet = FleetConfig { n_devices: 2, ..FleetConfig::default() };
        let mut s = DaemonSession::with_tenants(HwConfig::alveo_u250(), fleet, Some(tenants));
        let co = dataset("CO").unwrap();
        let pu = dataset("PU").unwrap();
        s.submit(Request::full(0, ZooModel::B2, co, 0.0)).unwrap();
        // The impossible deadline walks the cascade and sheds — a
        // recorded QoS decision the replay must re-derive.
        s.submit(Request::full(1, ZooModel::B1, co, 0.0)).unwrap();
        s.submit(Request::minibatch(0, ZooModel::B1, co, vec![5, 9], vec![8, 4], 3, 0.0))
            .unwrap();
        s.submit(Request::full(0, ZooModel::B7, pu, 0.0)).unwrap();
        s.drain();
        let trace = s.finalize();
        assert_eq!(trace.version, 3);
        assert!(trace.events.iter().any(|e| matches!(e, TraceEvent::Decision(_))));
        assert_eq!(verify(&trace).unwrap(), Vec::<String>::new());
        // Through a full encode/decode cycle too.
        let decoded = Trace::parse(&trace.encode()).unwrap();
        assert_eq!(verify(&decoded).unwrap(), Vec::<String>::new());
        // Dropping the recorded QoS decision stream is a named
        // divergence.
        let mut tampered = trace;
        tampered.events.retain(|e| !matches!(e, TraceEvent::Decision(_)));
        let div = verify(&tampered).unwrap();
        assert!(div.iter().any(|d| d.starts_with("decision events:")), "{div:?}");
    }

    #[test]
    fn verify_refuses_events_only_traces() {
        let mut trace = recorded_session();
        trace.responses.clear();
        trace.stats = None;
        let err = verify(&trace).unwrap_err().to_string();
        assert!(err.contains("no recorded responses"), "{err}");
    }
}
