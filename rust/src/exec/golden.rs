//! Whole-graph golden executor: runs the (optimized) IR directly over
//! full matrices — the ground truth the partition-centric functional
//! executor must reproduce bit-for-bit (rust backend) or to float
//! tolerance (PJRT backend).
//!
//! Two kernel sets run the same layer loop: [`golden_forward`] routes
//! through the optimized backend (`exec::kernels` — blocked GEMM, a
//! whole-graph destination-row CSR built once per run and reused across
//! aggregation layers, layer buffers recycled through a
//! [`BufferArena`]), while [`golden_forward_reference`] keeps the naive
//! scalar COO kernels (`ops::reference`) and per-call allocation — the
//! fixed baseline `BENCH_kernels.json` measures speedups against.

use super::arena::BufferArena;
use super::kernels;
use super::ops;
use crate::graph::{CooGraph, CsrSubshard};
use crate::ir::{LayerType, ModelIr};
use crate::isa::Activation;
use crate::util::Rng;
use std::collections::HashMap;

/// Deterministic per-layer weights for Linear layers: the same store
/// feeds the golden executor, the functional executor, and (exported as
/// PJRT literals) the whole-model HLO artifact.
#[derive(Clone, Debug)]
pub struct WeightStore {
    /// layer id -> (w: f_in x f_out row-major, b: f_out).
    weights: HashMap<u16, (Vec<f32>, Vec<f32>)>,
}

impl WeightStore {
    /// Xavier-ish random weights for every Linear layer of `ir`.
    pub fn deterministic(ir: &ModelIr, seed: u64) -> WeightStore {
        let mut weights = HashMap::new();
        for l in &ir.layers {
            if l.ltype == LayerType::Linear {
                let mut rng = Rng::new(seed ^ (l.id as u64) << 17);
                let scale = (2.0 / (l.f_in + l.f_out) as f32).sqrt();
                let w: Vec<f32> = (0..(l.f_in * l.f_out) as usize)
                    .map(|_| rng.normal() * scale)
                    .collect();
                // Zero bias: the paper's GNN layers (Eq. 3) are bias-free,
                // and the Aggregate<->Linear exchange (Theorem 1) is only
                // semantics-preserving for pure linear maps — A(XW + b)
                // != (AX)W + b unless b == 0. The bias path itself is
                // exercised by the kernel-level tests and BatchNorm fold.
                let b = vec![0f32; l.f_out as usize];
                weights.insert(l.id, (w, b));
            }
        }
        WeightStore { weights }
    }

    pub fn get(&self, layer_id: u16) -> (&[f32], &[f32]) {
        let (w, b) = self.weights.get(&layer_id).expect("no weights for layer");
        (w, b)
    }

    /// Total parameter bytes (for the PCIe T_comm accounting).
    pub fn total_bytes(&self) -> u64 {
        self.weights
            .values()
            .map(|(w, b)| ((w.len() + b.len()) * 4) as u64)
            .sum()
    }

    /// Content fingerprint (FNV-1a over layer ids, dims, and **every**
    /// weight/bias bit pattern, in sorted-layer order), used to tie a
    /// cached [`kernels::PackedWeightSet`] to the exact store it was
    /// packed from — any single changed value changes the fingerprint,
    /// so a stale pack can never be applied to different weights. One
    /// read-only O(total weights) pass, far cheaper than repacking.
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(0x100000001b3)
        }
        let mut ids: Vec<u16> = self.weights.keys().copied().collect();
        ids.sort_unstable();
        let mut h: u64 = 0xcbf29ce484222325;
        for id in ids {
            let (w, b) = &self.weights[&id];
            h = mix(h, id as u64);
            h = mix(h, w.len() as u64);
            h = mix(h, b.len() as u64);
            for &v in w.iter().chain(b) {
                h = mix(h, v.to_bits() as u64);
            }
        }
        h
    }
}

/// Execute the IR over the whole graph with the optimized kernels.
/// Returns the last layer's output (n_vertices x f_out, row-major).
///
/// Semantics per layer type (identical to the tile path):
/// * Aggregate uses the *current* edge weights — initially the graph's,
///   updated by any upstream Vector-Inner layer;
/// * Vector-Inner replaces edge weights with `<h_i, h_j>` (+ fused act);
/// * fused activations apply at layer output.
pub fn golden_forward(ir: &ModelIr, graph: &CooGraph, store: &WeightStore, x: &[f32]) -> Vec<f32> {
    let mut arena = BufferArena::new();
    golden_forward_in(ir, graph, store, x, &mut arena)
}

/// [`golden_forward`] with a caller-owned [`BufferArena`]: layer
/// buffers and the per-run edge-weight copy are recycled through it, so
/// repeated runs (e.g. an engine serving many requests) reuse the same
/// allocations.
pub fn golden_forward_in(
    ir: &ModelIr,
    graph: &CooGraph,
    store: &WeightStore,
    x: &[f32],
    arena: &mut BufferArena,
) -> Vec<f32> {
    forward_impl(ir, graph, store, x, arena, false)
}

/// [`golden_forward`] over the naive scalar kernels (`ops::reference`)
/// with per-call allocation — the fixed baseline the kernel-backend
/// bench and property tests compare against.
pub fn golden_forward_reference(
    ir: &ModelIr,
    graph: &CooGraph,
    store: &WeightStore,
    x: &[f32],
) -> Vec<f32> {
    let mut arena = BufferArena::new();
    forward_impl(ir, graph, store, x, &mut arena, true)
}

fn forward_impl(
    ir: &ModelIr,
    graph: &CooGraph,
    store: &WeightStore,
    x: &[f32],
    arena: &mut BufferArena,
    reference: bool,
) -> Vec<f32> {
    let n = graph.n();
    let f0 = ir.graph.feat_len as usize;
    assert_eq!(x.len(), n * f0, "input features shape");
    // Whole-graph destination-row CSR, built once on first use and
    // reused by every Aggregate / Vector-Inner layer (optimized path).
    let mut csr_cache: Option<CsrSubshard> = None;
    // outputs[layer id] = (buffer, f_out)
    let mut outputs: HashMap<u16, (Vec<f32>, usize)> = HashMap::new();
    let mut edge_w: Vec<f32> = arena.copy_f32(&graph.w);
    let mut last_id = 0u16;
    for l in &ir.layers {
        let f_in = l.f_in as usize;
        let input_of =
            |pid: u16, outputs: &HashMap<u16, (Vec<f32>, usize)>, arena: &mut BufferArena| {
                match outputs.get(&pid) {
                    Some((buf, _)) => arena.copy_f32(buf),
                    None => arena.copy_f32(x),
                }
            };
        let h_in = match l.parents.first() {
            Some(&p) => input_of(p, &outputs, arena),
            None => arena.copy_f32(x),
        };
        let act = if l.act_enabled { l.act } else { Activation::None };
        let out: Vec<f32> = match l.ltype {
            LayerType::Aggregate => {
                let aggop = l.aggop.unwrap();
                if reference {
                    let mut o = ops::reference::spdmm(
                        &graph.src, &graph.dst, &edge_w, &h_in, f_in, n, aggop,
                    );
                    ops::apply_act(&mut o, act);
                    arena.recycle_f32(h_in);
                    o
                } else {
                    let csr = csr_cache.get_or_insert_with(|| {
                        kernels::csr_from_coo(&graph.src, &graph.dst, n)
                    });
                    let neutral = match aggop {
                        crate::isa::AggOp::Sum | crate::isa::AggOp::Mean => 0.0f32,
                        crate::isa::AggOp::Max => f32::NEG_INFINITY,
                        crate::isa::AggOp::Min => f32::INFINITY,
                    };
                    let mut o = arena.take_f32_filled(n * f_in, neutral);
                    let mut touched = arena.take_u32(n);
                    kernels::spdmm_csr_into(csr, &edge_w, &h_in, f_in, aggop, &mut o, &mut touched);
                    if neutral != 0.0 {
                        for (r, &t) in touched.iter().enumerate() {
                            if t == 0 {
                                o[r * f_in..(r + 1) * f_in].fill(0.0);
                            }
                        }
                    }
                    arena.recycle_u32(touched);
                    ops::apply_act(&mut o, act);
                    arena.recycle_f32(h_in);
                    o
                }
            }
            LayerType::Linear => {
                let (w, b) = store.get(l.id);
                let f_out = l.f_out as usize;
                let o = if reference {
                    ops::reference::gemm_bias_act(&h_in, n, f_in, w, f_out, b, act)
                } else {
                    let mut o = arena.take_f32(n * f_out);
                    kernels::gemm_into(&h_in, n, f_in, w, f_out, b, &mut o);
                    ops::apply_act(&mut o, act);
                    o
                };
                arena.recycle_f32(h_in);
                o
            }
            LayerType::VectorInner => {
                if reference {
                    let mut ew = ops::reference::sddmm(&graph.src, &graph.dst, &h_in, &h_in, f_in);
                    ops::apply_act(&mut ew, act);
                    edge_w = ew;
                } else {
                    let csr = csr_cache.get_or_insert_with(|| {
                        kernels::csr_from_coo(&graph.src, &graph.dst, n)
                    });
                    let mut vals = arena.take_f32(graph.m());
                    kernels::sddmm_csr_into(csr, &h_in, &h_in, f_in, &mut vals);
                    // Scatter CSR slot order back to edge order.
                    for (slot, &v) in vals.iter().enumerate() {
                        edge_w[csr.perm[slot] as usize] = v;
                    }
                    arena.recycle_f32(vals);
                    ops::apply_act(&mut edge_w, act);
                }
                h_in // features pass through
            }
            LayerType::VectorAdd => {
                let a = h_in;
                let b = match l.parents.get(1) {
                    Some(&p) => input_of(p, &outputs, arena),
                    None => arena.copy_f32(&a),
                };
                let o = ops::vecadd(&a, &b, act);
                arena.recycle_f32(a);
                arena.recycle_f32(b);
                o
            }
            LayerType::Activation => {
                // An activation directly behind a Vector-Inner layer acts
                // on the edge weights it produced (GAT's edge-score
                // nonlinearity), not on the vertex features.
                let edge_parent = l
                    .parents
                    .first()
                    .map(|&p| {
                        ir.layers
                            .iter()
                            .any(|q| q.id == p && q.ltype == LayerType::VectorInner)
                    })
                    .unwrap_or(false);
                if edge_parent {
                    ops::apply_act(&mut edge_w, l.act);
                    h_in
                } else {
                    let mut o = h_in;
                    ops::apply_act(&mut o, l.act);
                    o
                }
            }
            LayerType::BatchNorm => h_in, // inference BN with unit scale
        };
        outputs.insert(l.id, (out, l.f_out as usize));
        last_id = l.id;
    }
    let result = outputs.remove(&last_id).unwrap().0;
    for (_, (buf, _)) in outputs.drain() {
        arena.recycle_f32(buf);
    }
    arena.recycle_f32(edge_w);
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphMeta, rmat::rmat_edges};
    use crate::ir::ZooModel;

    fn small_graph() -> CooGraph {
        let meta = GraphMeta::new("t", 64, 256, 16, 4);
        rmat_edges(meta, Default::default(), 3).gcn_normalized()
    }

    #[test]
    fn all_zoo_models_run_and_are_finite() {
        let g = small_graph();
        for m in crate::ir::ALL_MODELS {
            let ir = m.build(g.meta.clone());
            let store = WeightStore::deterministic(&ir, 42);
            let x = g.random_features(1);
            let out = golden_forward(&ir, &g, &store, &x);
            assert_eq!(out.len(), g.n() * g.meta.n_classes as usize, "{}", m.key());
            assert!(
                out.iter().all(|v| v.is_finite()),
                "{}: non-finite output",
                m.key()
            );
        }
    }

    #[test]
    fn weights_deterministic() {
        let g = small_graph();
        let ir = ZooModel::B1.build(g.meta.clone());
        let a = WeightStore::deterministic(&ir, 7);
        let b = WeightStore::deterministic(&ir, 7);
        assert_eq!(a.get(2).0, b.get(2).0);
        let c = WeightStore::deterministic(&ir, 8);
        assert_ne!(a.get(2).0, c.get(2).0);
    }

    #[test]
    fn fingerprint_covers_every_weight() {
        let g = small_graph();
        let ir = ZooModel::B1.build(g.meta.clone());
        let a = WeightStore::deterministic(&ir, 7);
        assert_eq!(a.fingerprint(), WeightStore::deterministic(&ir, 7).fingerprint());
        // Flipping ONE value anywhere must change the fingerprint (the
        // packed-weight cache key can never validate stale weights).
        let mut weights = a.weights.clone();
        let id = *weights.keys().next().unwrap();
        let (w, _) = weights.get_mut(&id).unwrap();
        let mid = w.len() / 2;
        w[mid] += 1.0;
        let b = WeightStore { weights };
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn order_optimization_preserves_numerics() {
        // The golden executor over the *optimized* IR must match the
        // unoptimized IR (Theorem 1's numeric content). GCN weights are
        // linear sums, so LA == AL up to float assoc.
        let g = small_graph();
        let ir0 = ZooModel::B1.build(g.meta.clone());
        let mut ir1 = ir0.clone();
        crate::compiler::order::optimize(&mut ir1);
        // Weight ids may sit at different layer ids after the exchange;
        // map by Linear order instead: rebuild store keyed per IR.
        let s0 = WeightStore::deterministic(&ir0, 11);
        // Transfer: i-th Linear of ir0 -> i-th Linear of ir1.
        let lin0: Vec<u16> = ir0
            .layers
            .iter()
            .filter(|l| l.ltype == LayerType::Linear)
            .map(|l| l.id)
            .collect();
        let lin1: Vec<u16> = ir1
            .layers
            .iter()
            .filter(|l| l.ltype == LayerType::Linear)
            .map(|l| l.id)
            .collect();
        let mut weights = HashMap::new();
        for (a, b) in lin0.iter().zip(&lin1) {
            let (w, bias) = s0.get(*a);
            weights.insert(*b, (w.to_vec(), bias.to_vec()));
        }
        let s1 = WeightStore { weights };
        let x = g.random_features(2);
        let y0 = golden_forward(&ir0, &g, &s0, &x);
        let y1 = golden_forward(&ir1, &g, &s1, &x);
        let scale = y0.iter().fold(1f32, |m, v| m.max(v.abs()));
        let max_err = y0
            .iter()
            .zip(&y1)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(
            max_err < 1e-3 * scale,
            "order exchange changed numerics: {max_err} (scale {scale})"
        );
    }

    #[test]
    fn fusion_preserves_numerics() {
        let g = small_graph();
        let ir0 = ZooModel::B6.build(g.meta.clone());
        let mut ir1 = ir0.clone();
        crate::compiler::fusion::fuse(&mut ir1);
        let s = WeightStore::deterministic(&ir0, 21);
        // Fusion never removes Linear layers, so ids persist.
        let x = g.random_features(3);
        let y0 = golden_forward(&ir0, &g, &s, &x);
        let y1 = golden_forward(&ir1, &g, &s, &x);
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
