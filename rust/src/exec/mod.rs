//! Functional execution: real GNN numerics for the compiled program.
//!
//! * [`ops`] — operator entry points on row-major `f32` buffers (the
//!   rust analogue of `python/compile/kernels/ref.py`): optimized
//!   kernels at the top level, the naive scalar originals under
//!   `ops::reference` as the measurable baseline,
//! * [`kernels`] — the optimized kernel backend: blocked/register-tiled
//!   GEMM over per-executable packed weight panels, destination-row CSR
//!   SpDMM/SDDMM, and row-block parallelism on scoped threads,
//! * [`arena`] — [`BufferArena`], the size-class buffer pool behind the
//!   zero-alloc steady-state hot loop,
//! * [`golden`] — whole-graph executor over the optimized IR: the ground
//!   truth every other execution path must match,
//! * [`functional`] — the partition-centric executor: runs the compiler's
//!   Tiling Blocks one by one through a [`functional::TileBackend`]
//!   (optimized rust kernels, the naive reference backend, or the PJRT
//!   runtime executing the AOT HLO kernels), proving that ISA ->
//!   schedule -> kernels compose functionally.

pub mod arena;
pub mod functional;
pub mod golden;
pub mod kernels;
pub mod ops;

pub use arena::{ArenaStats, BufferArena, DtypeStats};
pub use functional::{
    CountingBackend, FunctionalExecutor, ReferenceBackend, RustBackend, TileBackend,
};
pub use golden::{golden_forward, golden_forward_in, golden_forward_reference, WeightStore};
pub use kernels::{PackedWeightSet, PackedWeightSetI8, PackedWeights, PackedWeightsI8};
