//! Functional execution: real GNN numerics for the compiled program.
//!
//! * [`ops`] — dense/sparse reference operators on row-major `f32`
//!   buffers (the rust analogue of `python/compile/kernels/ref.py`),
//! * [`golden`] — whole-graph executor over the optimized IR: the ground
//!   truth every other execution path must match,
//! * [`functional`] — the partition-centric executor: runs the compiler's
//!   Tiling Blocks one by one through a [`functional::TileBackend`]
//!   (pure-rust ops, or the PJRT runtime executing the AOT HLO kernels),
//!   proving that ISA -> schedule -> kernels compose functionally.

pub mod functional;
pub mod golden;
pub mod ops;

pub use functional::{CountingBackend, FunctionalExecutor, RustBackend, TileBackend};
pub use golden::{golden_forward, WeightStore};
