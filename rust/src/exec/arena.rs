//! [`BufferArena`] — a size-class-keyed pool of reusable `f32`/`u32`/
//! `i8`/`i32` buffers for the execution hot loop.
//!
//! The functional executor allocates the same tile shapes over and over
//! (feature tiles, aggregation accumulators, per-edge value vectors —
//! and, in quantized mode, int8 operand tiles plus their i32
//! accumulators). The arena recycles those buffers instead of returning
//! them to the heap: a buffer is pooled under the largest power-of-two
//! size class its capacity covers, and `take` hands back any pooled
//! buffer whose class covers the requested length. After one warm run
//! every steady-state request is served from the pool —
//! [`ArenaStats::fresh`] stops growing (the escaping final output matrix
//! is the one exception; see `exec::functional`).
//!
//! Counters are kept twice: the flat aggregates (`fresh`/`reused`/
//! `recycled`) that the steady-state assertions use, and a per-dtype
//! breakdown ([`ArenaStats::by_f32`] .. [`ArenaStats::by_i32`]) so the
//! quantized path's pool behaviour is auditable separately from the f32
//! path it shares the arena with.
//!
//! The arena is deliberately not thread-safe: each executor (and each
//! serving device) owns its own arena, mirroring the per-overlay
//! Feature/Result buffers of the hardware. Kernel-internal parallelism
//! (`exec::kernels`) splits borrowed slices and never allocates.

use std::collections::HashMap;

/// Smallest pooled size class (elements). Tiny buffers are cheap to
/// allocate and pooling them would fragment the class map.
const MIN_CLASS: usize = 64;

/// Per-class cap on pooled buffers; extras are dropped so a pathological
/// workload cannot grow the pool without bound.
const MAX_PER_CLASS: usize = 64;

/// Per-dtype allocation counters (one row of the breakdown).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DtypeStats {
    /// Buffers newly allocated from the heap (pool misses).
    pub fresh: u64,
    /// Buffers served from the pool (pool hits).
    pub reused: u64,
    /// Buffers returned to the pool.
    pub recycled: u64,
}

/// Allocation counters for the zero-alloc steady-state guarantee:
/// flat aggregates plus the per-dtype breakdown.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Buffers newly allocated from the heap (pool misses), all dtypes.
    pub fresh: u64,
    /// Buffers served from the pool (pool hits), all dtypes.
    pub reused: u64,
    /// Buffers returned to the pool, all dtypes.
    pub recycled: u64,
    /// f32 tile/accumulator buffers.
    pub by_f32: DtypeStats,
    /// u32 flag / index scratch.
    pub by_u32: DtypeStats,
    /// int8 quantized operand tiles.
    pub by_i8: DtypeStats,
    /// i32 quantized accumulators.
    pub by_i32: DtypeStats,
}

impl ArenaStats {
    /// Fraction of takes served without touching the heap.
    pub fn hit_rate(&self) -> f64 {
        let total = self.fresh + self.reused;
        if total == 0 {
            return 0.0;
        }
        self.reused as f64 / total as f64
    }
}

/// A reusable-buffer pool keyed by power-of-two size class.
#[derive(Debug, Default)]
pub struct BufferArena {
    f32_pool: HashMap<usize, Vec<Vec<f32>>>,
    u32_pool: HashMap<usize, Vec<Vec<u32>>>,
    i8_pool: HashMap<usize, Vec<Vec<i8>>>,
    i32_pool: HashMap<usize, Vec<Vec<i32>>>,
    stats: ArenaStats,
}

/// Size class that must *hold* a buffer of `len`: the smallest pooled
/// power of two >= len.
fn class_for(len: usize) -> usize {
    len.next_power_of_two().max(MIN_CLASS)
}

/// Size class a buffer of `capacity` can *serve*: the largest pooled
/// power of two <= capacity (0 when the capacity is below the floor).
fn class_of_capacity(capacity: usize) -> usize {
    if capacity < MIN_CLASS {
        return 0;
    }
    if capacity.is_power_of_two() {
        capacity
    } else {
        capacity.next_power_of_two() >> 1
    }
}

/// Pool-or-allocate a `fill`-filled buffer of `len`; true when reused.
fn pool_take<T: Clone>(
    pool: &mut HashMap<usize, Vec<Vec<T>>>,
    len: usize,
    fill: T,
) -> (Vec<T>, bool) {
    let class = class_for(len);
    match pool.get_mut(&class).and_then(Vec::pop) {
        Some(mut buf) => {
            buf.clear();
            buf.resize(len, fill);
            (buf, true)
        }
        None => {
            let mut buf = Vec::with_capacity(class);
            buf.resize(len, fill);
            (buf, false)
        }
    }
}

/// Return a buffer to its size class; true when actually pooled.
fn pool_recycle<T>(pool: &mut HashMap<usize, Vec<Vec<T>>>, buf: Vec<T>) -> bool {
    let class = class_of_capacity(buf.capacity());
    if class == 0 {
        return false; // below the pooling floor: let it drop
    }
    let slot = pool.entry(class).or_default();
    if slot.len() < MAX_PER_CLASS {
        slot.push(buf);
        true
    } else {
        false
    }
}

impl BufferArena {
    pub fn new() -> BufferArena {
        BufferArena::default()
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    fn note_take(agg: &mut ArenaStats, per: impl FnOnce(&mut ArenaStats) -> &mut DtypeStats, reused: bool) {
        if reused {
            agg.reused += 1;
        } else {
            agg.fresh += 1;
        }
        let d = per(agg);
        if reused {
            d.reused += 1;
        } else {
            d.fresh += 1;
        }
    }

    /// A zero-filled f32 buffer of exactly `len` elements.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        self.take_f32_filled(len, 0.0)
    }

    /// A `fill`-filled f32 buffer of exactly `len` elements.
    pub fn take_f32_filled(&mut self, len: usize, fill: f32) -> Vec<f32> {
        let (buf, reused) = pool_take(&mut self.f32_pool, len, fill);
        Self::note_take(&mut self.stats, |s| &mut s.by_f32, reused);
        buf
    }

    /// A buffer holding a copy of `src`.
    pub fn copy_f32(&mut self, src: &[f32]) -> Vec<f32> {
        let mut buf = self.take_f32(src.len());
        buf.copy_from_slice(src);
        buf
    }

    /// Return an f32 buffer to the pool.
    pub fn recycle_f32(&mut self, buf: Vec<f32>) {
        if pool_recycle(&mut self.f32_pool, buf) {
            self.stats.recycled += 1;
            self.stats.by_f32.recycled += 1;
        }
    }

    /// A zero-filled u32 buffer of exactly `len` elements (flag /
    /// index scratch — e.g. touched-row bitmaps).
    pub fn take_u32(&mut self, len: usize) -> Vec<u32> {
        let (buf, reused) = pool_take(&mut self.u32_pool, len, 0);
        Self::note_take(&mut self.stats, |s| &mut s.by_u32, reused);
        buf
    }

    /// Return a u32 buffer to the pool.
    pub fn recycle_u32(&mut self, buf: Vec<u32>) {
        if pool_recycle(&mut self.u32_pool, buf) {
            self.stats.recycled += 1;
            self.stats.by_u32.recycled += 1;
        }
    }

    /// A zero-filled i8 buffer of exactly `len` elements (quantized
    /// operand tiles).
    pub fn take_i8(&mut self, len: usize) -> Vec<i8> {
        let (buf, reused) = pool_take(&mut self.i8_pool, len, 0);
        Self::note_take(&mut self.stats, |s| &mut s.by_i8, reused);
        buf
    }

    /// Return an i8 buffer to the pool.
    pub fn recycle_i8(&mut self, buf: Vec<i8>) {
        if pool_recycle(&mut self.i8_pool, buf) {
            self.stats.recycled += 1;
            self.stats.by_i8.recycled += 1;
        }
    }

    /// A zero-filled i32 buffer of exactly `len` elements (quantized
    /// accumulators).
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        let (buf, reused) = pool_take(&mut self.i32_pool, len, 0);
        Self::note_take(&mut self.stats, |s| &mut s.by_i32, reused);
        buf
    }

    /// Return an i32 buffer to the pool.
    pub fn recycle_i32(&mut self, buf: Vec<i32>) {
        if pool_recycle(&mut self.i32_pool, buf) {
            self.stats.recycled += 1;
            self.stats.by_i32.recycled += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_sized_and_filled() {
        let mut a = BufferArena::new();
        let b = a.take_f32_filled(100, f32::NEG_INFINITY);
        assert_eq!(b.len(), 100);
        assert!(b.iter().all(|&v| v == f32::NEG_INFINITY));
        assert_eq!(a.stats().fresh, 1);
    }

    #[test]
    fn recycle_then_take_reuses_without_reallocating() {
        let mut a = BufferArena::new();
        let b = a.take_f32(100); // class 128
        let cap = b.capacity();
        a.recycle_f32(b);
        // Any length in the same class reuses the same allocation.
        let c = a.take_f32_filled(120, 1.0);
        assert_eq!(c.capacity(), cap);
        assert_eq!(c.len(), 120);
        assert!(c.iter().all(|&v| v == 1.0));
        let s = a.stats();
        assert_eq!((s.fresh, s.reused, s.recycled), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn smaller_class_does_not_steal_larger_request() {
        let mut a = BufferArena::new();
        let small = a.take_f32(64);
        a.recycle_f32(small);
        // 1000 needs class 1024; the pooled class-64 buffer cannot serve
        // it, so this take is fresh (no hidden realloc-on-resize).
        let big = a.take_f32(1000);
        assert!(big.capacity() >= 1000);
        assert_eq!(a.stats().fresh, 2);
    }

    #[test]
    fn u32_pool_is_independent() {
        let mut a = BufferArena::new();
        let t = a.take_u32(100);
        assert!(t.iter().all(|&v| v == 0));
        a.recycle_u32(t);
        let t2 = a.take_u32(90);
        assert_eq!(t2.len(), 90);
        let s = a.stats();
        assert_eq!((s.fresh, s.reused), (1, 1));
    }

    #[test]
    fn quantized_pools_are_independent_and_recycle() {
        let mut a = BufferArena::new();
        let q = a.take_i8(200);
        assert!(q.iter().all(|&v| v == 0));
        let acc = a.take_i32(128);
        assert!(acc.iter().all(|&v| v == 0));
        a.recycle_i8(q);
        a.recycle_i32(acc);
        // Same classes reuse; the f32/u32 pools never serve them.
        let q2 = a.take_i8(130);
        let acc2 = a.take_i32(65);
        assert_eq!((q2.len(), acc2.len()), (130, 65));
        let s = a.stats();
        assert_eq!((s.fresh, s.reused, s.recycled), (2, 2, 2));
        assert_eq!((s.by_i8.fresh, s.by_i8.reused, s.by_i8.recycled), (1, 1, 1));
        assert_eq!((s.by_i32.fresh, s.by_i32.reused, s.by_i32.recycled), (1, 1, 1));
        assert_eq!(s.by_f32, DtypeStats::default());
        assert_eq!(s.by_u32, DtypeStats::default());
    }

    #[test]
    fn per_dtype_breakdown_sums_to_aggregates() {
        let mut a = BufferArena::new();
        for _ in 0..3 {
            let f = a.take_f32(100);
            let u = a.take_u32(100);
            let q = a.take_i8(100);
            let w = a.take_i32(100);
            a.recycle_f32(f);
            a.recycle_u32(u);
            a.recycle_i8(q);
            a.recycle_i32(w);
        }
        let s = a.stats();
        let rows = [s.by_f32, s.by_u32, s.by_i8, s.by_i32];
        assert_eq!(rows.iter().map(|r| r.fresh).sum::<u64>(), s.fresh);
        assert_eq!(rows.iter().map(|r| r.reused).sum::<u64>(), s.reused);
        assert_eq!(rows.iter().map(|r| r.recycled).sum::<u64>(), s.recycled);
        // After warm-up every dtype runs pool-hit-only.
        assert_eq!(s.fresh, 4);
        assert_eq!(s.reused, 8);
    }

    #[test]
    fn steady_state_is_allocation_free() {
        let mut a = BufferArena::new();
        // Warm-up: the shapes a fake workload uses.
        for &len in &[128usize, 8192, 64, 512] {
            let b = a.take_f32(len);
            a.recycle_f32(b);
        }
        let fresh_after_warmup = a.stats().fresh;
        for _ in 0..10 {
            for &len in &[128usize, 8192, 64, 512] {
                let b = a.take_f32(len);
                a.recycle_f32(b);
            }
        }
        assert_eq!(a.stats().fresh, fresh_after_warmup, "steady state allocated");
    }
}
