//! The optimized kernel backend: cache-blocked, register-tiled GEMM over
//! pre-packed weight panels, destination-row CSR SpDMM/SDDMM, and
//! row-block data parallelism on scoped OS threads.
//!
//! This is the software analogue of GraphAGILE's Adaptive Computation
//! Kernel datapath: one set of kernels behind `exec::functional`'s
//! [`super::TileBackend`] and the golden whole-graph path, tuned for the
//! cache hierarchy instead of the systolic array. The naive scalar
//! reference kernels are kept at `exec::ops::reference` — property tests
//! (`rust/tests/kernel_backend.rs`) pin these kernels against them, and
//! `cargo bench --bench kernel_backend` records the speedup.
//!
//! Design:
//! * **GEMM** — `out[M x N] = H[M x K] @ W + b` walks W in `NC`-column
//!   panels and `KC`-row blocks; an `MR`-row micro-kernel accumulates
//!   `MR` output-row segments in a stack-resident register block, so
//!   each loaded weight value is reused `MR` times and the panel stays
//!   cache-hot across the whole M sweep. Zero rows of H (post-ReLU
//!   sparsity) are skipped per quad. [`PackedWeights`] reorders W into
//!   the panel layout **once per executable** — not per tile call.
//! * **SpDMM / SDDMM** — subshards arrive as destination-row CSR
//!   ([`crate::graph::CsrSubshard`], built once at partition time), so
//!   aggregation is an independent reduction per output row: the
//!   accumulator row stays in registers/L1 across all of the row's
//!   edges instead of being re-fetched per random COO scatter, touched
//!   rows are free (a CSR row is non-empty), and rows are disjoint —
//!   which makes the parallel split trivially safe.
//! * **Parallelism** — `std::thread::scope` over contiguous row blocks,
//!   only above a work threshold (tiny tiles stay serial; spawning
//!   would cost more than it buys). The offline vendor set has no
//!   `rayon`, so the fan-out is hand-rolled on scoped threads; worker
//!   count comes from `GA_KERNEL_THREADS` (fallback `GA_BENCH_THREADS`,
//!   then `available_parallelism`), so benches and CI pin it for
//!   deterministic timing. Splits are row-disjoint, so results are
//!   bit-identical at any thread count.
//!
//! Nothing here allocates on the hot path: every kernel writes into
//! caller-provided buffers (see [`super::arena::BufferArena`]).

use super::golden::WeightStore;
use crate::graph::CsrSubshard;
use crate::ir::{LayerType, ModelIr};
use crate::isa::AggOp;
use std::collections::HashMap;

/// Feature columns per weight panel (L1-sized: NC * 4 B per acc row).
pub const NC: usize = 128;
/// K rows per panel block.
pub const KC: usize = 128;
/// Output rows per micro-kernel (register block height).
pub const MR: usize = 4;

/// Below this many flops (2*M*K*N) a GEMM runs serially.
const PAR_MIN_FLOPS: usize = 1 << 21;
/// Below this much edge work (nnz * f) SpDMM/SDDMM run serially.
const PAR_MIN_EDGE_WORK: usize = 1 << 19;

/// Worker count for the kernel fan-out: `GA_KERNEL_THREADS`, else
/// `GA_BENCH_THREADS` (the bench/CI pin), else the machine's available
/// parallelism; clamped to [1, 16]. Read per call so benches can flip
/// between single- and multi-threaded phases in one process.
pub fn kernel_threads() -> usize {
    let parse = |key: &str| std::env::var(key).ok().and_then(|v| v.parse::<usize>().ok());
    parse("GA_KERNEL_THREADS")
        .or_else(|| parse("GA_BENCH_THREADS"))
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
        .clamp(1, 16)
}

/// A Linear layer's weight matrix, reordered once into the panel layout
/// the blocked GEMM consumes: for each `NC`-column panel, the panel's
/// `k` row segments are stored contiguously (row `kk` of panel `p` is
/// `panels[p_base + kk * panel_width ..]`). Only the panels are stored
/// (packing is a permutation, so memory stays 1x the weights);
/// backends without a packed kernel reconstruct the row-major view via
/// [`PackedWeights::unpack`].
#[derive(Clone, Debug)]
pub struct PackedWeights {
    pub k: usize,
    pub n: usize,
    panels: Vec<f32>,
}

impl PackedWeights {
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedWeights {
        assert_eq!(w.len(), k * n, "weight shape");
        let mut panels = Vec::with_capacity(k * n);
        let mut j0 = 0;
        while j0 < n {
            let wp = (n - j0).min(NC);
            for kk in 0..k {
                panels.extend_from_slice(&w[kk * n + j0..kk * n + j0 + wp]);
            }
            j0 += wp;
        }
        PackedWeights { k, n, panels }
    }

    /// Reconstruct the original row-major (k x n) matrix — the exact
    /// inverse of [`PackedWeights::pack`]. Allocates; only fallback
    /// paths without a packed kernel (PJRT, the naive reference) use
    /// it.
    pub fn unpack(&self) -> Vec<f32> {
        let mut w = vec![0f32; self.k * self.n];
        let mut panel_base = 0usize;
        let mut j0 = 0usize;
        while j0 < self.n {
            let wp = (self.n - j0).min(NC);
            for kk in 0..self.k {
                w[kk * self.n + j0..kk * self.n + j0 + wp].copy_from_slice(
                    &self.panels[panel_base + kk * wp..panel_base + (kk + 1) * wp],
                );
            }
            panel_base += self.k * wp;
            j0 += wp;
        }
        w
    }
}

/// Every Linear layer's [`PackedWeights`], packed once per
/// (executable, weight store) pair and reused across runs — the
/// "weights are packed once, not per call" lifecycle. The fingerprint
/// ties the set to the exact [`WeightStore`] contents so a cached set
/// is never applied to different weights.
#[derive(Clone, Debug, Default)]
pub struct PackedWeightSet {
    pub fingerprint: u64,
    by_layer: HashMap<u16, PackedWeights>,
}

impl PackedWeightSet {
    pub fn build(ir: &ModelIr, store: &WeightStore) -> PackedWeightSet {
        let mut by_layer = HashMap::new();
        for l in &ir.layers {
            if l.ltype == LayerType::Linear {
                let (w, _) = store.get(l.id);
                by_layer
                    .insert(l.id, PackedWeights::pack(w, l.f_in as usize, l.f_out as usize));
            }
        }
        PackedWeightSet { fingerprint: store.fingerprint(), by_layer }
    }

    pub fn get(&self, layer_id: u16) -> &PackedWeights {
        self.by_layer.get(&layer_id).expect("no packed weights for layer")
    }
}

/// Weight source for the blocked GEMM: raw row-major or packed panels.
#[derive(Clone, Copy)]
enum WSrc<'a> {
    /// (row-major k x n weights, n)
    Raw(&'a [f32], usize),
    Panels(&'a [f32]),
}

#[inline(always)]
fn wseg<'a>(wsrc: WSrc<'a>, kk: usize, j0: usize, wp: usize, panel_base: usize) -> &'a [f32] {
    match wsrc {
        WSrc::Raw(w, n) => &w[kk * n + j0..kk * n + j0 + wp],
        WSrc::Panels(p) => &p[panel_base + kk * wp..panel_base + (kk + 1) * wp],
    }
}

/// Serial blocked GEMM over one block of rows: out = h @ w + b.
fn gemm_block(h: &[f32], rows: usize, k: usize, n: usize, wsrc: WSrc, b: &[f32], out: &mut [f32]) {
    for r in 0..rows {
        out[r * n..(r + 1) * n].copy_from_slice(b);
    }
    let mut panel_base = 0usize;
    let mut j0 = 0usize;
    while j0 < n {
        let wp = (n - j0).min(NC);
        let mut k0 = 0usize;
        while k0 < k {
            let kb = (k - k0).min(KC);
            let mut r = 0usize;
            while r + MR <= rows {
                // Register block: MR output-row segments on the stack,
                // so the inner loop has no aliasing and vectorizes.
                let mut acc = [[0f32; NC]; MR];
                for (q, accq) in acc.iter_mut().enumerate() {
                    let at = (r + q) * n + j0;
                    accq[..wp].copy_from_slice(&out[at..at + wp]);
                }
                let [acc0, acc1, acc2, acc3] = &mut acc;
                for kk in k0..k0 + kb {
                    let a0 = h[r * k + kk];
                    let a1 = h[(r + 1) * k + kk];
                    let a2 = h[(r + 2) * k + kk];
                    let a3 = h[(r + 3) * k + kk];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue; // post-ReLU row sparsity
                    }
                    let wrow = wseg(wsrc, kk, j0, wp, panel_base);
                    let it = acc0[..wp]
                        .iter_mut()
                        .zip(acc1[..wp].iter_mut())
                        .zip(acc2[..wp].iter_mut())
                        .zip(acc3[..wp].iter_mut())
                        .zip(wrow.iter());
                    for ((((o0, o1), o2), o3), &wv) in it {
                        *o0 += a0 * wv;
                        *o1 += a1 * wv;
                        *o2 += a2 * wv;
                        *o3 += a3 * wv;
                    }
                }
                for (q, accq) in acc.iter().enumerate() {
                    let at = (r + q) * n + j0;
                    out[at..at + wp].copy_from_slice(&accq[..wp]);
                }
                r += MR;
            }
            // Remainder rows, one at a time.
            while r < rows {
                for kk in k0..k0 + kb {
                    let a = h[r * k + kk];
                    if a == 0.0 {
                        continue;
                    }
                    let wrow = wseg(wsrc, kk, j0, wp, panel_base);
                    let orow = &mut out[r * n + j0..r * n + j0 + wp];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += a * wv;
                    }
                }
                r += 1;
            }
            k0 += kb;
        }
        panel_base += k * wp;
        j0 += wp;
    }
}

fn gemm_parallel(h: &[f32], m: usize, k: usize, n: usize, wsrc: WSrc, b: &[f32], out: &mut [f32]) {
    let threads = kernel_threads();
    if threads <= 1 || 2 * m * k * n < PAR_MIN_FLOPS || m < 2 * MR {
        gemm_block(h, m, k, n, wsrc, b, out);
        return;
    }
    // Contiguous row chunks (multiples of MR keep quads whole); rows
    // are disjoint, so the split is safe and bit-identical to serial.
    let per = (m.div_ceil(threads)).div_ceil(MR) * MR;
    std::thread::scope(|s| {
        for (hc, oc) in h.chunks(per * k).zip(out.chunks_mut(per * n)) {
            let rows = oc.len() / n;
            s.spawn(move || gemm_block(hc, rows, k, n, wsrc, b, oc));
        }
    });
}

/// out(m x n) = h(m x k) @ w(k x n) + b — blocked and row-parallel,
/// reading W row-major in place (the ad-hoc path, e.g. densified
/// adjacency tiles; Linear layers go through [`gemm_packed_into`]).
pub fn gemm_into(h: &[f32], m: usize, k: usize, w: &[f32], n: usize, b: &[f32], out: &mut [f32]) {
    assert_eq!(h.len(), m * k, "h shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(b.len(), n, "bias shape");
    assert_eq!(out.len(), m * n, "out shape");
    gemm_parallel(h, m, k, n, WSrc::Raw(w, n), b, out);
}

/// out(m x n) = h @ W + b against weights packed once per executable.
pub fn gemm_packed_into(h: &[f32], m: usize, pw: &PackedWeights, b: &[f32], out: &mut [f32]) {
    assert_eq!(h.len(), m * pw.k, "h shape");
    assert_eq!(b.len(), pw.n, "bias shape");
    assert_eq!(out.len(), m * pw.n, "out shape");
    gemm_parallel(h, m, pw.k, pw.n, WSrc::Panels(&pw.panels), b, out);
}

/// Serial CSR aggregation over local rows [r0, r0 + acc_rows/f):
/// accumulates each row's edges into its accumulator row in place.
fn spdmm_rows(
    csr: &CsrSubshard,
    ew: &[f32],
    h: &[f32],
    f: usize,
    aggop: AggOp,
    acc_rows: &mut [f32],
    touched: &mut [u32],
    r0: usize,
) {
    for (ri, orow) in acc_rows.chunks_mut(f).enumerate() {
        let r = r0 + ri;
        let lo = csr.row_offsets[r] as usize;
        let hi = csr.row_offsets[r + 1] as usize;
        if lo == hi {
            continue;
        }
        touched[ri] = 1;
        match aggop {
            AggOp::Sum | AggOp::Mean => {
                for slot in lo..hi {
                    let c = csr.cols[slot] as usize;
                    let wv = ew[csr.perm[slot] as usize];
                    let hrow = &h[c * f..(c + 1) * f];
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o += wv * hv;
                    }
                }
            }
            AggOp::Max => {
                for slot in lo..hi {
                    let c = csr.cols[slot] as usize;
                    let wv = ew[csr.perm[slot] as usize];
                    let hrow = &h[c * f..(c + 1) * f];
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o = o.max(wv * hv);
                    }
                }
            }
            AggOp::Min => {
                for slot in lo..hi {
                    let c = csr.cols[slot] as usize;
                    let wv = ew[csr.perm[slot] as usize];
                    let hrow = &h[c * f..(c + 1) * f];
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o = o.min(wv * hv);
                    }
                }
            }
        }
    }
}

/// Aggregate one CSR subshard *into* `acc` (rows x f, pre-initialized
/// with the aggregation's neutral element — or earlier subshards'
/// partials: in-place accumulation makes cross-subshard combining
/// free). Rows with at least one edge are flagged in `touched`
/// (callers zero untouched Max/Min rows afterwards; the kernel
/// convention). Edge weights are gathered through `csr.perm`, so
/// SDDMM-updated weights stay live. Row-parallel above the work
/// threshold; rows are disjoint, so any thread count is bit-identical.
pub fn spdmm_csr_into(
    csr: &CsrSubshard,
    ew: &[f32],
    h: &[f32],
    f: usize,
    aggop: AggOp,
    acc: &mut [f32],
    touched: &mut [u32],
) {
    let rows = csr.rows as usize;
    assert_eq!(acc.len(), rows * f, "acc shape");
    assert_eq!(touched.len(), rows, "touched shape");
    assert_eq!(ew.len(), csr.nnz(), "edge weights");
    if f == 0 || rows == 0 {
        return;
    }
    let threads = kernel_threads();
    if threads <= 1 || csr.nnz() * f < PAR_MIN_EDGE_WORK || rows < 2 {
        spdmm_rows(csr, ew, h, f, aggop, acc, touched, 0);
        return;
    }
    let per = rows.div_ceil(threads).max(1);
    std::thread::scope(|s| {
        for (ci, (ac, tc)) in
            acc.chunks_mut(per * f).zip(touched.chunks_mut(per)).enumerate()
        {
            let r0 = ci * per;
            s.spawn(move || spdmm_rows(csr, ew, h, f, aggop, ac, tc, r0));
        }
    });
}

/// Inner product with 4-way accumulator ILP (reassociates the sum; the
/// equivalence tests carry an epsilon for it).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let ac = a.chunks_exact(4);
    let bc = b.chunks_exact(4);
    let ra = ac.remainder();
    let rb = bc.remainder();
    let mut acc = [0f32; 4];
    for (ca, cb) in ac.zip(bc) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for (&x, &y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

/// Serial SDDMM over local rows [r0, r1): `vals_part[slot - base]` =
/// `<hl[cols[slot]], hr[row]>` with `base = row_offsets[r0]`.
fn sddmm_rows(
    csr: &CsrSubshard,
    hl: &[f32],
    hr: &[f32],
    f: usize,
    vals_part: &mut [f32],
    r0: usize,
    r1: usize,
) {
    let base = csr.row_offsets[r0] as usize;
    for r in r0..r1 {
        let hrrow = &hr[r * f..(r + 1) * f];
        for slot in csr.row(r) {
            let c = csr.cols[slot] as usize;
            vals_part[slot - base] = dot(&hl[c * f..(c + 1) * f], hrrow);
        }
    }
}

/// Per-edge inner products in CSR slot order: vals[slot] =
/// `<hl[csr.cols[slot]], hr[row(slot)]>`. Grouping by destination row
/// keeps the `hr` row hot across the row's edges; callers scatter
/// `vals` back to edge order through `csr.perm`.
pub fn sddmm_csr_into(csr: &CsrSubshard, hl: &[f32], hr: &[f32], f: usize, vals: &mut [f32]) {
    let rows = csr.rows as usize;
    assert_eq!(vals.len(), csr.nnz(), "vals shape");
    if csr.nnz() == 0 {
        return;
    }
    let threads = kernel_threads();
    if threads <= 1 || csr.nnz() * f < PAR_MIN_EDGE_WORK || rows < 2 {
        sddmm_rows(csr, hl, hr, f, vals, 0, rows);
        return;
    }
    // Contiguous row ranges; `vals` splits raggedly at row boundaries
    // (slot ranges are disjoint by construction).
    let per = rows.div_ceil(threads).max(1);
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = vals;
        let mut r0 = 0usize;
        while r0 < rows {
            let r1 = (r0 + per).min(rows);
            let len = (csr.row_offsets[r1] - csr.row_offsets[r0]) as usize;
            let (part, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            s.spawn(move || sddmm_rows(csr, hl, hr, f, part, r0, r1));
            r0 = r1;
        }
    });
}

/// Whole-graph COO -> destination-row CSR (the golden path builds this
/// once per run and reuses it across aggregation layers).
pub fn csr_from_coo(src: &[u32], dst: &[u32], n_out: usize) -> CsrSubshard {
    CsrSubshard::from_local_coo(dst.iter().copied(), src.iter().copied(), n_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_gemm(h: &[f32], m: usize, k: usize, w: &[f32], n: usize, b: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = b[j] as f64;
                for kk in 0..k {
                    s += h[i * k + kk] as f64 * w[kk * n + j] as f64;
                }
                out[i * n + j] = s as f32;
            }
        }
        out
    }

    #[test]
    fn blocked_gemm_matches_f64_reference_over_shapes() {
        let mut rng = Rng::new(71);
        for &(m, k, n) in
            &[(1usize, 1usize, 1usize), (3, 5, 7), (4, 128, 128), (17, 200, 33), (65, 96, 130)]
        {
            let h: Vec<f32> = (0..m * k)
                .map(|_| if rng.below(4) == 0 { 0.0 } else { rng.normal() })
                .collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let want = naive_gemm(&h, m, k, &w, n, &b);
            let mut got = vec![0f32; m * n];
            gemm_into(&h, m, k, &w, n, &b, &mut got);
            for (g, wv) in got.iter().zip(&want) {
                assert!((g - wv).abs() < 1e-3 * (1.0 + wv.abs()), "{m}x{k}x{n}: {g} vs {wv}");
            }
            // Packed panels compute the same partial-sum order as the
            // raw path (identical blocking), so results match exactly.
            let pw = PackedWeights::pack(&w, k, n);
            let mut packed = vec![0f32; m * n];
            gemm_packed_into(&h, m, &pw, &b, &mut packed);
            assert_eq!(got, packed, "{m}x{k}x{n}: packed != raw");
        }
    }

    #[test]
    fn spdmm_csr_basics_and_touched() {
        // Ring 0->1->2->3->0 plus an untouched vertex 4.
        let src = [0u32, 1, 2, 3];
        let dst = [1u32, 2, 3, 0];
        let csr = csr_from_coo(&src, &dst, 5);
        let ew = [1f32, 1.0, 1.0, 1.0];
        let h = [10f32, 11., 12., 13., 99.];
        let mut acc = vec![0f32; 5];
        let mut touched = vec![0u32; 5];
        spdmm_csr_into(&csr, &ew, &h, 1, AggOp::Sum, &mut acc, &mut touched);
        assert_eq!(acc, vec![13.0, 10.0, 11.0, 12.0, 0.0]);
        assert_eq!(touched, vec![1, 1, 1, 1, 0]);
    }

    #[test]
    fn spdmm_csr_max_keeps_negative_maxima() {
        // The satellite fix: a legitimate negative maximum must survive
        // (the old !is_finite full scan only worked by accident; the
        // touched flags make the untouched-row zeroing exact).
        let src = [0u32];
        let dst = [1u32];
        let csr = csr_from_coo(&src, &dst, 3);
        let mut acc = vec![f32::NEG_INFINITY; 3];
        let mut touched = vec![0u32; 3];
        spdmm_csr_into(&csr, &[1.0], &[-5.0, 0.0, 0.0], 1, AggOp::Max, &mut acc, &mut touched);
        assert_eq!(touched, vec![0, 1, 0]);
        assert_eq!(acc[1], -5.0);
    }

    #[test]
    fn sddmm_csr_inner_products_via_perm() {
        let h = [1f32, 2., 3., 4.]; // 2 rows x 2
        let src = [0u32, 1];
        let dst = [1u32, 1];
        let csr = csr_from_coo(&src, &dst, 2);
        let mut vals = vec![0f32; 2];
        sddmm_csr_into(&csr, &h, &h, 2, &mut vals);
        // Scatter back to edge order through perm.
        let mut by_edge = vec![0f32; 2];
        for (slot, &v) in vals.iter().enumerate() {
            by_edge[csr.perm[slot] as usize] = v;
        }
        assert_eq!(by_edge, vec![1. * 3. + 2. * 4., 3. * 3. + 4. * 4.]);
    }

    #[test]
    fn dot_matches_sequential_sum() {
        let mut rng = Rng::new(9);
        for len in [0usize, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let want: f32 = a.iter().zip(&b).map(|(&x, &y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "len {len}");
        }
    }

    #[test]
    fn packing_roundtrips_and_is_a_permutation() {
        let mut rng = Rng::new(12);
        let (k, n) = (5usize, NC + 7); // two panels, one ragged
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let pw = PackedWeights::pack(&w, k, n);
        // unpack is the exact inverse of pack.
        assert_eq!(pw.unpack(), w);
        let mut sorted_raw: Vec<u32> = w.iter().map(|v| v.to_bits()).collect();
        let mut sorted_packed: Vec<u32> = pw.panels.iter().map(|v| v.to_bits()).collect();
        sorted_raw.sort_unstable();
        sorted_packed.sort_unstable();
        assert_eq!(sorted_raw, sorted_packed);
    }
}
