//! The optimized kernel backend: cache-blocked, register-tiled GEMM over
//! pre-packed weight panels, destination-row CSR SpDMM/SDDMM, and
//! row-block data parallelism on scoped OS threads.
//!
//! This is the software analogue of GraphAGILE's Adaptive Computation
//! Kernel datapath: one set of kernels behind `exec::functional`'s
//! [`super::TileBackend`] and the golden whole-graph path, tuned for the
//! cache hierarchy instead of the systolic array. The naive scalar
//! reference kernels are kept at `exec::ops::reference` — property tests
//! (`rust/tests/kernel_backend.rs`) pin these kernels against them, and
//! `cargo bench --bench kernel_backend` records the speedup.
//!
//! Design:
//! * **GEMM** — `out[M x N] = H[M x K] @ W + b` walks W in `NC`-column
//!   panels and `KC`-row blocks; an `MR`-row micro-kernel accumulates
//!   `MR` output-row segments in a stack-resident register block, so
//!   each loaded weight value is reused `MR` times and the panel stays
//!   cache-hot across the whole M sweep. Zero rows of H (post-ReLU
//!   sparsity) are skipped per quad. [`PackedWeights`] reorders W into
//!   the panel layout **once per executable** — not per tile call.
//! * **SpDMM / SDDMM** — subshards arrive as destination-row CSR
//!   ([`crate::graph::CsrSubshard`], built once at partition time), so
//!   aggregation is an independent reduction per output row: the
//!   accumulator row stays in registers/L1 across all of the row's
//!   edges instead of being re-fetched per random COO scatter, touched
//!   rows are free (a CSR row is non-empty), and rows are disjoint —
//!   which makes the parallel split trivially safe.
//! * **Parallelism** — `std::thread::scope` over contiguous row blocks,
//!   only above a work threshold (tiny tiles stay serial; spawning
//!   would cost more than it buys). The offline vendor set has no
//!   `rayon`, so the fan-out is hand-rolled on scoped threads; worker
//!   count comes from `GA_KERNEL_THREADS` (fallback `GA_BENCH_THREADS`,
//!   then `available_parallelism`), so benches and CI pin it for
//!   deterministic timing. Splits are row-disjoint, so results are
//!   bit-identical at any thread count.
//!
//! Nothing here allocates on the hot path: every kernel writes into
//! caller-provided buffers (see [`super::arena::BufferArena`]).

use super::golden::WeightStore;
use crate::graph::CsrSubshard;
use crate::ir::{LayerType, ModelIr};
use crate::isa::AggOp;
use std::collections::HashMap;

/// Feature columns per weight panel (L1-sized: NC * 4 B per acc row).
pub const NC: usize = 128;
/// K rows per panel block.
pub const KC: usize = 128;
/// Output rows per micro-kernel (register block height).
pub const MR: usize = 4;

/// Below this many flops (2*M*K*N) a GEMM runs serially.
const PAR_MIN_FLOPS: usize = 1 << 21;
/// Below this much edge work (nnz * f) SpDMM/SDDMM run serially.
const PAR_MIN_EDGE_WORK: usize = 1 << 19;

/// Worker count for the kernel fan-out: `GA_KERNEL_THREADS`, else
/// `GA_BENCH_THREADS` (the bench/CI pin), else the machine's available
/// parallelism; clamped to [1, 16]. Read per call so benches can flip
/// between single- and multi-threaded phases in one process.
pub fn kernel_threads() -> usize {
    let parse = |key: &str| std::env::var(key).ok().and_then(|v| v.parse::<usize>().ok());
    parse("GA_KERNEL_THREADS")
        .or_else(|| parse("GA_BENCH_THREADS"))
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
        .clamp(1, 16)
}

/// A Linear layer's weight matrix, reordered once into the panel layout
/// the blocked GEMM consumes: for each `NC`-column panel, the panel's
/// `k` row segments are stored contiguously (row `kk` of panel `p` is
/// `panels[p_base + kk * panel_width ..]`). Only the panels are stored
/// (packing is a permutation, so memory stays 1x the weights);
/// backends without a packed kernel reconstruct the row-major view via
/// [`PackedWeights::unpack`].
#[derive(Clone, Debug)]
pub struct PackedWeights {
    pub k: usize,
    pub n: usize,
    panels: Vec<f32>,
}

impl PackedWeights {
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedWeights {
        assert_eq!(w.len(), k * n, "weight shape");
        let mut panels = Vec::with_capacity(k * n);
        let mut j0 = 0;
        while j0 < n {
            let wp = (n - j0).min(NC);
            for kk in 0..k {
                panels.extend_from_slice(&w[kk * n + j0..kk * n + j0 + wp]);
            }
            j0 += wp;
        }
        PackedWeights { k, n, panels }
    }

    /// Reconstruct the original row-major (k x n) matrix — the exact
    /// inverse of [`PackedWeights::pack`]. Allocates; only fallback
    /// paths without a packed kernel (PJRT, the naive reference) use
    /// it.
    pub fn unpack(&self) -> Vec<f32> {
        let mut w = vec![0f32; self.k * self.n];
        let mut panel_base = 0usize;
        let mut j0 = 0usize;
        while j0 < self.n {
            let wp = (self.n - j0).min(NC);
            for kk in 0..self.k {
                w[kk * self.n + j0..kk * self.n + j0 + wp].copy_from_slice(
                    &self.panels[panel_base + kk * wp..panel_base + (kk + 1) * wp],
                );
            }
            panel_base += self.k * wp;
            j0 += wp;
        }
        w
    }
}

/// Every Linear layer's [`PackedWeights`], packed once per
/// (executable, weight store) pair and reused across runs — the
/// "weights are packed once, not per call" lifecycle. The fingerprint
/// ties the set to the exact [`WeightStore`] contents so a cached set
/// is never applied to different weights.
#[derive(Clone, Debug, Default)]
pub struct PackedWeightSet {
    pub fingerprint: u64,
    by_layer: HashMap<u16, PackedWeights>,
}

impl PackedWeightSet {
    pub fn build(ir: &ModelIr, store: &WeightStore) -> PackedWeightSet {
        let mut by_layer = HashMap::new();
        for l in &ir.layers {
            if l.ltype == LayerType::Linear {
                let (w, _) = store.get(l.id);
                by_layer
                    .insert(l.id, PackedWeights::pack(w, l.f_in as usize, l.f_out as usize));
            }
        }
        PackedWeightSet { fingerprint: store.fingerprint(), by_layer }
    }

    pub fn get(&self, layer_id: u16) -> &PackedWeights {
        self.by_layer.get(&layer_id).expect("no packed weights for layer")
    }
}

/// Weight source for the blocked GEMM: raw row-major or packed panels.
#[derive(Clone, Copy)]
enum WSrc<'a> {
    /// (row-major k x n weights, n)
    Raw(&'a [f32], usize),
    Panels(&'a [f32]),
}

#[inline(always)]
fn wseg<'a>(wsrc: WSrc<'a>, kk: usize, j0: usize, wp: usize, panel_base: usize) -> &'a [f32] {
    match wsrc {
        WSrc::Raw(w, n) => &w[kk * n + j0..kk * n + j0 + wp],
        WSrc::Panels(p) => &p[panel_base + kk * wp..panel_base + (kk + 1) * wp],
    }
}

/// Serial blocked GEMM over one block of rows: out = h @ w + b.
fn gemm_block(h: &[f32], rows: usize, k: usize, n: usize, wsrc: WSrc, b: &[f32], out: &mut [f32]) {
    for r in 0..rows {
        out[r * n..(r + 1) * n].copy_from_slice(b);
    }
    let mut panel_base = 0usize;
    let mut j0 = 0usize;
    while j0 < n {
        let wp = (n - j0).min(NC);
        let mut k0 = 0usize;
        while k0 < k {
            let kb = (k - k0).min(KC);
            let mut r = 0usize;
            while r + MR <= rows {
                // Register block: MR output-row segments on the stack,
                // so the inner loop has no aliasing and vectorizes.
                let mut acc = [[0f32; NC]; MR];
                for (q, accq) in acc.iter_mut().enumerate() {
                    let at = (r + q) * n + j0;
                    accq[..wp].copy_from_slice(&out[at..at + wp]);
                }
                let [acc0, acc1, acc2, acc3] = &mut acc;
                for kk in k0..k0 + kb {
                    let a0 = h[r * k + kk];
                    let a1 = h[(r + 1) * k + kk];
                    let a2 = h[(r + 2) * k + kk];
                    let a3 = h[(r + 3) * k + kk];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue; // post-ReLU row sparsity
                    }
                    let wrow = wseg(wsrc, kk, j0, wp, panel_base);
                    let it = acc0[..wp]
                        .iter_mut()
                        .zip(acc1[..wp].iter_mut())
                        .zip(acc2[..wp].iter_mut())
                        .zip(acc3[..wp].iter_mut())
                        .zip(wrow.iter());
                    for ((((o0, o1), o2), o3), &wv) in it {
                        *o0 += a0 * wv;
                        *o1 += a1 * wv;
                        *o2 += a2 * wv;
                        *o3 += a3 * wv;
                    }
                }
                for (q, accq) in acc.iter().enumerate() {
                    let at = (r + q) * n + j0;
                    out[at..at + wp].copy_from_slice(&accq[..wp]);
                }
                r += MR;
            }
            // Remainder rows, one at a time.
            while r < rows {
                for kk in k0..k0 + kb {
                    let a = h[r * k + kk];
                    if a == 0.0 {
                        continue;
                    }
                    let wrow = wseg(wsrc, kk, j0, wp, panel_base);
                    let orow = &mut out[r * n + j0..r * n + j0 + wp];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += a * wv;
                    }
                }
                r += 1;
            }
            k0 += kb;
        }
        panel_base += k * wp;
        j0 += wp;
    }
}

fn gemm_parallel(h: &[f32], m: usize, k: usize, n: usize, wsrc: WSrc, b: &[f32], out: &mut [f32]) {
    let threads = kernel_threads();
    if threads <= 1 || 2 * m * k * n < PAR_MIN_FLOPS || m < 2 * MR {
        gemm_block(h, m, k, n, wsrc, b, out);
        return;
    }
    // Contiguous row chunks (multiples of MR keep quads whole); rows
    // are disjoint, so the split is safe and bit-identical to serial.
    let per = (m.div_ceil(threads)).div_ceil(MR) * MR;
    std::thread::scope(|s| {
        for (hc, oc) in h.chunks(per * k).zip(out.chunks_mut(per * n)) {
            let rows = oc.len() / n;
            s.spawn(move || gemm_block(hc, rows, k, n, wsrc, b, oc));
        }
    });
}

/// out(m x n) = h(m x k) @ w(k x n) + b — blocked and row-parallel,
/// reading W row-major in place (the ad-hoc path, e.g. densified
/// adjacency tiles; Linear layers go through [`gemm_packed_into`]).
pub fn gemm_into(h: &[f32], m: usize, k: usize, w: &[f32], n: usize, b: &[f32], out: &mut [f32]) {
    assert_eq!(h.len(), m * k, "h shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(b.len(), n, "bias shape");
    assert_eq!(out.len(), m * n, "out shape");
    gemm_parallel(h, m, k, n, WSrc::Raw(w, n), b, out);
}

/// out(m x n) = h @ W + b against weights packed once per executable.
pub fn gemm_packed_into(h: &[f32], m: usize, pw: &PackedWeights, b: &[f32], out: &mut [f32]) {
    assert_eq!(h.len(), m * pw.k, "h shape");
    assert_eq!(b.len(), pw.n, "bias shape");
    assert_eq!(out.len(), m * pw.n, "out shape");
    gemm_parallel(h, m, pw.k, pw.n, WSrc::Panels(&pw.panels), b, out);
}

/// Serial CSR aggregation over local rows [r0, r0 + acc_rows/f):
/// accumulates each row's edges into its accumulator row in place.
fn spdmm_rows(
    csr: &CsrSubshard,
    ew: &[f32],
    h: &[f32],
    f: usize,
    aggop: AggOp,
    acc_rows: &mut [f32],
    touched: &mut [u32],
    r0: usize,
) {
    for (ri, orow) in acc_rows.chunks_mut(f).enumerate() {
        let r = r0 + ri;
        let lo = csr.row_offsets[r] as usize;
        let hi = csr.row_offsets[r + 1] as usize;
        if lo == hi {
            continue;
        }
        touched[ri] = 1;
        match aggop {
            AggOp::Sum | AggOp::Mean => {
                for slot in lo..hi {
                    let c = csr.cols[slot] as usize;
                    let wv = ew[csr.perm[slot] as usize];
                    let hrow = &h[c * f..(c + 1) * f];
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o += wv * hv;
                    }
                }
            }
            AggOp::Max => {
                for slot in lo..hi {
                    let c = csr.cols[slot] as usize;
                    let wv = ew[csr.perm[slot] as usize];
                    let hrow = &h[c * f..(c + 1) * f];
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o = o.max(wv * hv);
                    }
                }
            }
            AggOp::Min => {
                for slot in lo..hi {
                    let c = csr.cols[slot] as usize;
                    let wv = ew[csr.perm[slot] as usize];
                    let hrow = &h[c * f..(c + 1) * f];
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o = o.min(wv * hv);
                    }
                }
            }
        }
    }
}

/// Aggregate one CSR subshard *into* `acc` (rows x f, pre-initialized
/// with the aggregation's neutral element — or earlier subshards'
/// partials: in-place accumulation makes cross-subshard combining
/// free). Rows with at least one edge are flagged in `touched`
/// (callers zero untouched Max/Min rows afterwards; the kernel
/// convention). Edge weights are gathered through `csr.perm`, so
/// SDDMM-updated weights stay live. Row-parallel above the work
/// threshold; rows are disjoint, so any thread count is bit-identical.
pub fn spdmm_csr_into(
    csr: &CsrSubshard,
    ew: &[f32],
    h: &[f32],
    f: usize,
    aggop: AggOp,
    acc: &mut [f32],
    touched: &mut [u32],
) {
    let rows = csr.rows as usize;
    assert_eq!(acc.len(), rows * f, "acc shape");
    assert_eq!(touched.len(), rows, "touched shape");
    assert_eq!(ew.len(), csr.nnz(), "edge weights");
    if f == 0 || rows == 0 {
        return;
    }
    let threads = kernel_threads();
    if threads <= 1 || csr.nnz() * f < PAR_MIN_EDGE_WORK || rows < 2 {
        spdmm_rows(csr, ew, h, f, aggop, acc, touched, 0);
        return;
    }
    let per = rows.div_ceil(threads).max(1);
    std::thread::scope(|s| {
        for (ci, (ac, tc)) in
            acc.chunks_mut(per * f).zip(touched.chunks_mut(per)).enumerate()
        {
            let r0 = ci * per;
            s.spawn(move || spdmm_rows(csr, ew, h, f, aggop, ac, tc, r0));
        }
    });
}

/// Inner product with 4-way accumulator ILP (reassociates the sum; the
/// equivalence tests carry an epsilon for it).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let ac = a.chunks_exact(4);
    let bc = b.chunks_exact(4);
    let ra = ac.remainder();
    let rb = bc.remainder();
    let mut acc = [0f32; 4];
    for (ca, cb) in ac.zip(bc) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for (&x, &y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

/// Serial SDDMM over local rows [r0, r1): `vals_part[slot - base]` =
/// `<hl[cols[slot]], hr[row]>` with `base = row_offsets[r0]`.
fn sddmm_rows(
    csr: &CsrSubshard,
    hl: &[f32],
    hr: &[f32],
    f: usize,
    vals_part: &mut [f32],
    r0: usize,
    r1: usize,
) {
    let base = csr.row_offsets[r0] as usize;
    for r in r0..r1 {
        let hrrow = &hr[r * f..(r + 1) * f];
        for slot in csr.row(r) {
            let c = csr.cols[slot] as usize;
            vals_part[slot - base] = dot(&hl[c * f..(c + 1) * f], hrrow);
        }
    }
}

/// Per-edge inner products in CSR slot order: vals[slot] =
/// `<hl[csr.cols[slot]], hr[row(slot)]>`. Grouping by destination row
/// keeps the `hr` row hot across the row's edges; callers scatter
/// `vals` back to edge order through `csr.perm`.
pub fn sddmm_csr_into(csr: &CsrSubshard, hl: &[f32], hr: &[f32], f: usize, vals: &mut [f32]) {
    let rows = csr.rows as usize;
    assert_eq!(vals.len(), csr.nnz(), "vals shape");
    if csr.nnz() == 0 {
        return;
    }
    let threads = kernel_threads();
    if threads <= 1 || csr.nnz() * f < PAR_MIN_EDGE_WORK || rows < 2 {
        sddmm_rows(csr, hl, hr, f, vals, 0, rows);
        return;
    }
    // Contiguous row ranges; `vals` splits raggedly at row boundaries
    // (slot ranges are disjoint by construction).
    let per = rows.div_ceil(threads).max(1);
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = vals;
        let mut r0 = 0usize;
        while r0 < rows {
            let r1 = (r0 + per).min(rows);
            let len = (csr.row_offsets[r1] - csr.row_offsets[r0]) as usize;
            let (part, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            s.spawn(move || sddmm_rows(csr, hl, hr, f, part, r0, r1));
            r0 = r1;
        }
    });
}

/// Whole-graph COO -> destination-row CSR (the golden path builds this
/// once per run and reuses it across aggregation layers).
pub fn csr_from_coo(src: &[u32], dst: &[u32], n_out: usize) -> CsrSubshard {
    CsrSubshard::from_local_coo(dst.iter().copied(), src.iter().copied(), n_out)
}

// ---------------------------------------------------------------------
// int8 datapath: symmetric quantization, packed i8 panels, and i32-
// accumulating GEMM/SpDMM twins of the f32 kernels above. Integer
// accumulation is exactly associative, so the row-parallel splits are
// bit-identical at any thread count without the f32 epsilon caveats.
// ---------------------------------------------------------------------

/// Symmetric int8 quantization: `q = clamp(round(v / scale), -127, 127)`
/// with round-half-away-from-zero. The sign-carrying 0.5 offset plus a
/// truncating cast keeps the loop branch-free and autovectorizable (no
/// libm round call), and `round(0) == 0` preserves post-ReLU zeros
/// exactly — the GEMM's zero-quad skip keeps working on quantized rows.
pub fn quantize_into(src: &[f32], scale: f32, out: &mut [i8]) {
    assert_eq!(src.len(), out.len(), "quantize shape");
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    for (o, &v) in out.iter_mut().zip(src) {
        let t = v * inv;
        let r = (t + 0.5f32.copysign(t)) as i32;
        *o = r.clamp(-127, 127) as i8;
    }
}

/// Dequantize an i32 accumulator tile back to f32: `out[r][j] =
/// acc[r][j] * s + b[j]`. `s` is the product of the two operand scales
/// (GEMM: `s_x * s_w`); the caller fuses the layer activation into the
/// same pass over `out` (`exec::functional`).
pub fn dequant_bias_into(acc: &[i32], n: usize, s: f32, b: &[f32], out: &mut [f32]) {
    assert_eq!(acc.len(), out.len(), "dequant shape");
    assert_eq!(b.len(), n, "bias shape");
    for (orow, arow) in out.chunks_mut(n).zip(acc.chunks(n)) {
        for ((o, &a), &bv) in orow.iter_mut().zip(arow).zip(b) {
            *o = a as f32 * s + bv;
        }
    }
}

/// A Linear layer's weights, symmetrically quantized to int8 at `scale`
/// and reordered into the same panel layout as [`PackedWeights`] (the
/// blocked GEMM walks both identically). Packed once per executable.
#[derive(Clone, Debug)]
pub struct PackedWeightsI8 {
    pub k: usize,
    pub n: usize,
    /// The symmetric scale the panels were quantized with (w = q * scale).
    pub scale: f32,
    panels: Vec<i8>,
}

impl PackedWeightsI8 {
    pub fn pack(w: &[f32], k: usize, n: usize, scale: f32) -> PackedWeightsI8 {
        assert_eq!(w.len(), k * n, "weight shape");
        let mut q = vec![0i8; k * n];
        quantize_into(w, scale, &mut q);
        let mut panels = Vec::with_capacity(k * n);
        let mut j0 = 0;
        while j0 < n {
            let wp = (n - j0).min(NC);
            for kk in 0..k {
                panels.extend_from_slice(&q[kk * n + j0..kk * n + j0 + wp]);
            }
            j0 += wp;
        }
        PackedWeightsI8 { k, n, scale, panels }
    }
}

/// Every quantized Linear layer's [`PackedWeightsI8`], keyed like
/// [`PackedWeightSet`] and built lazily on the first quantized run (the
/// weight scale is a pure function of the weights, so one i8 set serves
/// every program compiled against the same store).
#[derive(Clone, Debug, Default)]
pub struct PackedWeightSetI8 {
    pub fingerprint: u64,
    by_layer: HashMap<u16, PackedWeightsI8>,
}

impl PackedWeightSetI8 {
    /// Quantize-and-pack every Linear layer listed in `scales`
    /// (`(layer_id, w_scale)` pairs — `exec` stays independent of the
    /// calibration pass that derives them).
    pub fn build(ir: &ModelIr, store: &WeightStore, scales: &[(u16, f32)]) -> PackedWeightSetI8 {
        let want: HashMap<u16, f32> = scales.iter().copied().collect();
        let mut by_layer = HashMap::new();
        for l in &ir.layers {
            if l.ltype == LayerType::Linear {
                if let Some(&s) = want.get(&l.id) {
                    let (w, _) = store.get(l.id);
                    by_layer.insert(
                        l.id,
                        PackedWeightsI8::pack(w, l.f_in as usize, l.f_out as usize, s),
                    );
                }
            }
        }
        PackedWeightSetI8 { fingerprint: store.fingerprint(), by_layer }
    }

    pub fn get(&self, layer_id: u16) -> &PackedWeightsI8 {
        self.by_layer.get(&layer_id).expect("no packed i8 weights for layer")
    }
}

/// Serial blocked int8 GEMM over one block of rows: `acc += hq @ wq`
/// with i32 accumulation. The caller zero-fills `acc`; bias and
/// dequantization run in the f32 epilogue. Same NC/KC/MR blocking as
/// the f32 kernel, with a k-pair inner loop: two i8 products summed in
/// i16 (`|p| <= 2 * 127^2 = 32258 < i16::MAX`) before one widening add,
/// which halves the widening work and maps onto packed multiply-add.
fn gemm_i8_block(hq: &[i8], rows: usize, k: usize, n: usize, panels: &[i8], acc: &mut [i32]) {
    let mut panel_base = 0usize;
    let mut j0 = 0usize;
    while j0 < n {
        let wp = (n - j0).min(NC);
        let mut k0 = 0usize;
        while k0 < k {
            let kb = (k - k0).min(KC);
            let mut r = 0usize;
            while r + MR <= rows {
                let mut accq = [[0i32; NC]; MR];
                let [acc0, acc1, acc2, acc3] = &mut accq;
                let (acc0, acc1) = (&mut acc0[..wp], &mut acc1[..wp]);
                let (acc2, acc3) = (&mut acc2[..wp], &mut acc3[..wp]);
                let mut kk = k0;
                while kk + 2 <= k0 + kb {
                    let a00 = hq[r * k + kk] as i16;
                    let a01 = hq[r * k + kk + 1] as i16;
                    let a10 = hq[(r + 1) * k + kk] as i16;
                    let a11 = hq[(r + 1) * k + kk + 1] as i16;
                    let a20 = hq[(r + 2) * k + kk] as i16;
                    let a21 = hq[(r + 2) * k + kk + 1] as i16;
                    let a30 = hq[(r + 3) * k + kk] as i16;
                    let a31 = hq[(r + 3) * k + kk + 1] as i16;
                    kk += 2;
                    if (a00 | a01 | a10 | a11 | a20 | a21 | a30 | a31) == 0 {
                        continue; // post-ReLU sparsity survives quantization
                    }
                    let w0 = &panels[panel_base + (kk - 2) * wp..][..wp];
                    let w1 = &panels[panel_base + (kk - 1) * wp..][..wp];
                    for i in 0..wp {
                        let (wv0, wv1) = (w0[i] as i16, w1[i] as i16);
                        acc0[i] += (a00 * wv0 + a01 * wv1) as i32;
                        acc1[i] += (a10 * wv0 + a11 * wv1) as i32;
                        acc2[i] += (a20 * wv0 + a21 * wv1) as i32;
                        acc3[i] += (a30 * wv0 + a31 * wv1) as i32;
                    }
                }
                if kk < k0 + kb {
                    let a0 = hq[r * k + kk] as i32;
                    let a1 = hq[(r + 1) * k + kk] as i32;
                    let a2 = hq[(r + 2) * k + kk] as i32;
                    let a3 = hq[(r + 3) * k + kk] as i32;
                    if (a0 | a1 | a2 | a3) != 0 {
                        let w0 = &panels[panel_base + kk * wp..][..wp];
                        for i in 0..wp {
                            let wv = w0[i] as i32;
                            acc0[i] += a0 * wv;
                            acc1[i] += a1 * wv;
                            acc2[i] += a2 * wv;
                            acc3[i] += a3 * wv;
                        }
                    }
                }
                for (q, accq) in accq.iter().enumerate() {
                    let at = (r + q) * n + j0;
                    for (o, &a) in acc[at..at + wp].iter_mut().zip(&accq[..wp]) {
                        *o += a;
                    }
                }
                r += MR;
            }
            while r < rows {
                for kk in k0..k0 + kb {
                    let a = hq[r * k + kk] as i32;
                    if a == 0 {
                        continue;
                    }
                    let wrow = &panels[panel_base + kk * wp..][..wp];
                    let orow = &mut acc[r * n + j0..r * n + j0 + wp];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += a * wv as i32;
                    }
                }
                r += 1;
            }
            k0 += kb;
        }
        panel_base += k * wp;
        j0 += wp;
    }
}

/// `acc(m x n) += hq @ Wq` against int8 panels packed once per
/// executable. Row-parallel like the f32 kernel; i32 accumulation is
/// exact, so any thread count produces identical bits.
pub fn gemm_i8_packed_into(hq: &[i8], m: usize, pw: &PackedWeightsI8, acc: &mut [i32]) {
    assert_eq!(hq.len(), m * pw.k, "h shape");
    assert_eq!(acc.len(), m * pw.n, "acc shape");
    let (k, n) = (pw.k, pw.n);
    let threads = kernel_threads();
    if threads <= 1 || 2 * m * k * n < PAR_MIN_FLOPS || m < 2 * MR {
        gemm_i8_block(hq, m, k, n, &pw.panels, acc);
        return;
    }
    let per = (m.div_ceil(threads)).div_ceil(MR) * MR;
    std::thread::scope(|s| {
        for (hc, oc) in hq.chunks(per * k).zip(acc.chunks_mut(per * n)) {
            let rows = oc.len() / n;
            let panels = &pw.panels;
            s.spawn(move || gemm_i8_block(hc, rows, k, n, panels, oc));
        }
    });
}

/// Serial int8 CSR aggregation over local rows [r0, r0 + acc_rows/f):
/// Sum semantics with i32 accumulation (Mean divides at dequant time).
/// Edge pairs share one i16 widening add, mirroring the GEMM inner loop.
fn spdmm_i8_rows(
    csr: &CsrSubshard,
    ewq: &[i8],
    hq: &[i8],
    f: usize,
    acc_rows: &mut [i32],
    touched: &mut [u32],
    r0: usize,
) {
    for (ri, orow) in acc_rows.chunks_mut(f).enumerate() {
        let r = r0 + ri;
        let lo = csr.row_offsets[r] as usize;
        let hi = csr.row_offsets[r + 1] as usize;
        if lo == hi {
            continue;
        }
        touched[ri] = 1;
        let mut slot = lo;
        while slot + 2 <= hi {
            let c0 = csr.cols[slot] as usize;
            let c1 = csr.cols[slot + 1] as usize;
            let w0 = ewq[csr.perm[slot] as usize] as i16;
            let w1 = ewq[csr.perm[slot + 1] as usize] as i16;
            let h0 = &hq[c0 * f..(c0 + 1) * f];
            let h1 = &hq[c1 * f..(c1 + 1) * f];
            for ((o, &v0), &v1) in orow.iter_mut().zip(h0).zip(h1) {
                *o += (w0 * v0 as i16 + w1 * v1 as i16) as i32;
            }
            slot += 2;
        }
        if slot < hi {
            let c = csr.cols[slot] as usize;
            let wv = ewq[csr.perm[slot] as usize] as i32;
            let hrow = &hq[c * f..(c + 1) * f];
            for (o, &hv) in orow.iter_mut().zip(hrow) {
                *o += wv * hv as i32;
            }
        }
    }
}

/// int8 twin of [`spdmm_csr_into`] for Sum/Mean aggregation: i32 row
/// reductions over quantized features and edge weights (Mean's division
/// happens in the f32 dequant epilogue, where it is exact). `acc` may
/// carry earlier subshards' partials — integer accumulation makes the
/// cross-subshard combine order-independent.
pub fn spdmm_csr_i8_into(
    csr: &CsrSubshard,
    ewq: &[i8],
    hq: &[i8],
    f: usize,
    acc: &mut [i32],
    touched: &mut [u32],
) {
    let rows = csr.rows as usize;
    assert_eq!(acc.len(), rows * f, "acc shape");
    assert_eq!(touched.len(), rows, "touched shape");
    assert_eq!(ewq.len(), csr.nnz(), "edge weights");
    if f == 0 || rows == 0 {
        return;
    }
    let threads = kernel_threads();
    if threads <= 1 || csr.nnz() * f < PAR_MIN_EDGE_WORK || rows < 2 {
        spdmm_i8_rows(csr, ewq, hq, f, acc, touched, 0);
        return;
    }
    let per = rows.div_ceil(threads).max(1);
    std::thread::scope(|s| {
        for (ci, (ac, tc)) in acc.chunks_mut(per * f).zip(touched.chunks_mut(per)).enumerate() {
            let r0 = ci * per;
            s.spawn(move || spdmm_i8_rows(csr, ewq, hq, f, ac, tc, r0));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_gemm(h: &[f32], m: usize, k: usize, w: &[f32], n: usize, b: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = b[j] as f64;
                for kk in 0..k {
                    s += h[i * k + kk] as f64 * w[kk * n + j] as f64;
                }
                out[i * n + j] = s as f32;
            }
        }
        out
    }

    #[test]
    fn blocked_gemm_matches_f64_reference_over_shapes() {
        let mut rng = Rng::new(71);
        for &(m, k, n) in
            &[(1usize, 1usize, 1usize), (3, 5, 7), (4, 128, 128), (17, 200, 33), (65, 96, 130)]
        {
            let h: Vec<f32> = (0..m * k)
                .map(|_| if rng.below(4) == 0 { 0.0 } else { rng.normal() })
                .collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let want = naive_gemm(&h, m, k, &w, n, &b);
            let mut got = vec![0f32; m * n];
            gemm_into(&h, m, k, &w, n, &b, &mut got);
            for (g, wv) in got.iter().zip(&want) {
                assert!((g - wv).abs() < 1e-3 * (1.0 + wv.abs()), "{m}x{k}x{n}: {g} vs {wv}");
            }
            // Packed panels compute the same partial-sum order as the
            // raw path (identical blocking), so results match exactly.
            let pw = PackedWeights::pack(&w, k, n);
            let mut packed = vec![0f32; m * n];
            gemm_packed_into(&h, m, &pw, &b, &mut packed);
            assert_eq!(got, packed, "{m}x{k}x{n}: packed != raw");
        }
    }

    #[test]
    fn spdmm_csr_basics_and_touched() {
        // Ring 0->1->2->3->0 plus an untouched vertex 4.
        let src = [0u32, 1, 2, 3];
        let dst = [1u32, 2, 3, 0];
        let csr = csr_from_coo(&src, &dst, 5);
        let ew = [1f32, 1.0, 1.0, 1.0];
        let h = [10f32, 11., 12., 13., 99.];
        let mut acc = vec![0f32; 5];
        let mut touched = vec![0u32; 5];
        spdmm_csr_into(&csr, &ew, &h, 1, AggOp::Sum, &mut acc, &mut touched);
        assert_eq!(acc, vec![13.0, 10.0, 11.0, 12.0, 0.0]);
        assert_eq!(touched, vec![1, 1, 1, 1, 0]);
    }

    #[test]
    fn spdmm_csr_max_keeps_negative_maxima() {
        // The satellite fix: a legitimate negative maximum must survive
        // (the old !is_finite full scan only worked by accident; the
        // touched flags make the untouched-row zeroing exact).
        let src = [0u32];
        let dst = [1u32];
        let csr = csr_from_coo(&src, &dst, 3);
        let mut acc = vec![f32::NEG_INFINITY; 3];
        let mut touched = vec![0u32; 3];
        spdmm_csr_into(&csr, &[1.0], &[-5.0, 0.0, 0.0], 1, AggOp::Max, &mut acc, &mut touched);
        assert_eq!(touched, vec![0, 1, 0]);
        assert_eq!(acc[1], -5.0);
    }

    #[test]
    fn sddmm_csr_inner_products_via_perm() {
        let h = [1f32, 2., 3., 4.]; // 2 rows x 2
        let src = [0u32, 1];
        let dst = [1u32, 1];
        let csr = csr_from_coo(&src, &dst, 2);
        let mut vals = vec![0f32; 2];
        sddmm_csr_into(&csr, &h, &h, 2, &mut vals);
        // Scatter back to edge order through perm.
        let mut by_edge = vec![0f32; 2];
        for (slot, &v) in vals.iter().enumerate() {
            by_edge[csr.perm[slot] as usize] = v;
        }
        assert_eq!(by_edge, vec![1. * 3. + 2. * 4., 3. * 3. + 4. * 4.]);
    }

    #[test]
    fn dot_matches_sequential_sum() {
        let mut rng = Rng::new(9);
        for len in [0usize, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let want: f32 = a.iter().zip(&b).map(|(&x, &y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "len {len}");
        }
    }

    #[test]
    fn quantize_rounds_clamps_and_keeps_zeros() {
        let src = [0.0f32, 0.05, -0.05, 1.0, -1.0, 2.5, -2.5];
        let mut q = vec![0i8; src.len()];
        quantize_into(&src, 1.0 / 127.0, &mut q);
        // 0 stays exactly 0; +-0.05 rounds to +-6 (0.05*127 = 6.35);
        // +-1.0 hits the full range; out-of-range saturates.
        assert_eq!(q, vec![0, 6, -6, 127, -127, 127, -127]);
        // Half-away rounding: 0.5 quanta rounds up in magnitude.
        let mut q2 = vec![0i8; 2];
        quantize_into(&[1.5, -1.5], 1.0, &mut q2);
        assert_eq!(q2, vec![2, -2]);
    }

    fn naive_gemm_i32(hq: &[i8], m: usize, k: usize, wq: &[i8], n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0i32;
                for kk in 0..k {
                    s += hq[i * k + kk] as i32 * wq[kk * n + j] as i32;
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    #[test]
    fn blocked_i8_gemm_is_exact_over_shapes() {
        let mut rng = Rng::new(77);
        for &(m, k, n) in
            &[(1usize, 1usize, 1usize), (3, 5, 7), (4, 128, 128), (17, 201, 33), (65, 96, 130)]
        {
            // Full-range i8 activations with a zero-row sprinkle (the
            // quad-skip path must stay exact).
            let hq: Vec<i8> = (0..m * k)
                .map(|_| if rng.below(4) == 0 { 0 } else { (rng.below(255) as i32 - 127) as i8 })
                .collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let pw = PackedWeightsI8::pack(&w, k, n, 3.0 / 127.0);
            let mut wq = vec![0i8; k * n];
            quantize_into(&w, 3.0 / 127.0, &mut wq);
            let want = naive_gemm_i32(&hq, m, k, &wq, n);
            let mut got = vec![0i32; m * n];
            gemm_i8_packed_into(&hq, m, &pw, &mut got);
            assert_eq!(got, want, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn i8_gemm_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (64usize, 128usize, 128usize);
        let hq: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let pw = PackedWeightsI8::pack(&w, k, n, 2.0 / 127.0);
        let prev = std::env::var("GA_KERNEL_THREADS").ok();
        let run = |t: &str| {
            std::env::set_var("GA_KERNEL_THREADS", t);
            let mut out = vec![0i32; m * n];
            gemm_i8_packed_into(&hq, m, &pw, &mut out);
            out
        };
        let (one, four) = (run("1"), run("4"));
        match prev {
            Some(v) => std::env::set_var("GA_KERNEL_THREADS", v),
            None => std::env::remove_var("GA_KERNEL_THREADS"),
        }
        assert_eq!(one, four);
    }

    #[test]
    fn spdmm_i8_sums_exactly_with_odd_and_even_degrees() {
        // Vertex 0 has degree 3 (odd: exercises the pair remainder),
        // vertex 1 degree 2, vertex 2 untouched.
        let src = [1u32, 2, 3, 0, 3];
        let dst = [0u32, 0, 0, 1, 1];
        let csr = csr_from_coo(&src, &dst, 4);
        let ewq: Vec<i8> = vec![2, 3, -4, 5, 7];
        let hq: Vec<i8> = vec![10, -20, 30, 40]; // f = 1
        let mut acc = vec![0i32; 4];
        let mut touched = vec![0u32; 4];
        spdmm_csr_i8_into(&csr, &ewq, &hq, 1, &mut acc, &mut touched);
        // Row 0: 2*h[1] + 3*h[2] + (-4)*h[3] = -40 + 90 - 160 = -110.
        // Row 1: 5*h[0] + 7*h[3] = 50 + 280 = 330.
        assert_eq!(acc, vec![-110, 330, 0, 0]);
        assert_eq!(touched, vec![1, 1, 0, 0]);
    }

    #[test]
    fn dequant_applies_scale_and_bias() {
        let acc = [100i32, -200, 0, 50];
        let b = [1.0f32, -1.0];
        let mut out = vec![0f32; 4];
        dequant_bias_into(&acc, 2, 0.01, &b, &mut out);
        assert_eq!(out, vec![2.0, -3.0, 1.0, -0.5]);
    }

    #[test]
    fn i8_packing_matches_quantized_rowmajor() {
        let mut rng = Rng::new(13);
        let (k, n) = (5usize, NC + 7);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let scale = 4.0 / 127.0;
        let pw = PackedWeightsI8::pack(&w, k, n, scale);
        let mut q = vec![0i8; k * n];
        quantize_into(&w, scale, &mut q);
        // The panel layout is the same permutation as the f32 pack:
        // multiset equality plus a spot check of the first panel row.
        let mut a = pw.panels.clone();
        let mut b = q.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        assert_eq!(&pw.panels[..NC], &q[..NC]);
    }

    #[test]
    fn packing_roundtrips_and_is_a_permutation() {
        let mut rng = Rng::new(12);
        let (k, n) = (5usize, NC + 7); // two panels, one ragged
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let pw = PackedWeights::pack(&w, k, n);
        // unpack is the exact inverse of pack.
        assert_eq!(pw.unpack(), w);
        let mut sorted_raw: Vec<u32> = w.iter().map(|v| v.to_bits()).collect();
        let mut sorted_packed: Vec<u32> = pw.panels.iter().map(|v| v.to_bits()).collect();
        sorted_raw.sort_unstable();
        sorted_packed.sort_unstable();
        assert_eq!(sorted_raw, sorted_packed);
    }
}
