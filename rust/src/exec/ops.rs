//! Reference operators on row-major `f32` buffers — the rust analogue of
//! the pure-jnp oracle (`python/compile/kernels/ref.py`). These back the
//! golden executor, the `RustBackend` tile executor, and the naive-CPU
//! baseline measurements.

use crate::isa::{Activation, AggOp};

/// out(m x n) = h(m x k) @ w(k x n) + b, then activation.
pub fn gemm_bias_act(
    h: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    b: &[f32],
    act: Activation,
) -> Vec<f32> {
    assert_eq!(h.len(), m * k, "h shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(b.len(), n, "bias shape");
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let hrow = &h[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        orow.copy_from_slice(b);
        for (kk, &hv) in hrow.iter().enumerate() {
            if hv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += hv * wv;
            }
        }
    }
    apply_act(&mut out, act);
    out
}

/// Edge-centric SpDMM: out(n_out x f) = AggOp over edges (src, dst, w)
/// of w * h[src]; `src`/`dst` index into `h` rows / `out` rows.
pub fn spdmm(
    src: &[u32],
    dst: &[u32],
    ew: &[f32],
    h: &[f32],
    f: usize,
    n_out: usize,
    aggop: AggOp,
) -> Vec<f32> {
    let init = match aggop {
        AggOp::Sum | AggOp::Mean => 0.0f32,
        AggOp::Max => f32::NEG_INFINITY,
        AggOp::Min => f32::INFINITY,
    };
    let mut out = vec![init; n_out * f];
    for ((&s, &d), &w) in src.iter().zip(dst).zip(ew) {
        let hrow = &h[s as usize * f..(s as usize + 1) * f];
        let orow = &mut out[d as usize * f..(d as usize + 1) * f];
        match aggop {
            AggOp::Sum | AggOp::Mean => {
                for (o, &hv) in orow.iter_mut().zip(hrow) {
                    *o += w * hv;
                }
            }
            AggOp::Max => {
                for (o, &hv) in orow.iter_mut().zip(hrow) {
                    *o = o.max(w * hv);
                }
            }
            AggOp::Min => {
                for (o, &hv) in orow.iter_mut().zip(hrow) {
                    *o = o.min(w * hv);
                }
            }
        }
    }
    // Untouched vertices produce 0 (matching the kernel/ref convention).
    if init != 0.0 {
        for o in out.iter_mut() {
            if !o.is_finite() {
                *o = 0.0;
            }
        }
    }
    out
}

/// Combine two partial aggregation tiles in place (cross-subshard).
pub fn combine_partials(acc: &mut [f32], part: &[f32], aggop: AggOp) {
    assert_eq!(acc.len(), part.len());
    match aggop {
        AggOp::Sum | AggOp::Mean => {
            for (a, &p) in acc.iter_mut().zip(part) {
                *a += p;
            }
        }
        AggOp::Max => {
            for (a, &p) in acc.iter_mut().zip(part) {
                *a = a.max(p);
            }
        }
        AggOp::Min => {
            for (a, &p) in acc.iter_mut().zip(part) {
                *a = a.min(p);
            }
        }
    }
}

/// SDDMM: per-edge inner products of rows of `hl` and `hr`.
pub fn sddmm(src: &[u32], dst: &[u32], hl: &[f32], hr: &[f32], f: usize) -> Vec<f32> {
    src.iter()
        .zip(dst)
        .map(|(&s, &d)| {
            let a = &hl[s as usize * f..(s as usize + 1) * f];
            let b = &hr[d as usize * f..(d as usize + 1) * f];
            a.iter().zip(b).map(|(&x, &y)| x * y).sum()
        })
        .collect()
}

/// Elementwise a + b with fused activation.
pub fn vecadd(a: &[f32], b: &[f32], act: Activation) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    let mut out: Vec<f32> = a.iter().zip(b).map(|(&x, &y)| x + y).collect();
    apply_act(&mut out, act);
    out
}

/// In-place activation (matches `ref.py::apply_act_ref` semantics).
pub fn apply_act(x: &mut [f32], act: Activation) {
    match act {
        Activation::None => {}
        Activation::Relu => x.iter_mut().for_each(|v| *v = v.max(0.0)),
        Activation::LRelu => x
            .iter_mut()
            .for_each(|v| *v = if *v > 0.0 { *v } else { 0.01 * *v }),
        Activation::PRelu => x
            .iter_mut()
            .for_each(|v| *v = if *v > 0.0 { *v } else { 0.25 * *v }),
        Activation::Swish => x.iter_mut().for_each(|v| {
            *v = *v / (1.0 + (-*v).exp());
        }),
        Activation::Exp => x.iter_mut().for_each(|v| *v = v.exp()),
        Activation::Sigmoid => x.iter_mut().for_each(|v| *v = 1.0 / (1.0 + (-*v).exp())),
        Activation::Elu => x
            .iter_mut()
            .for_each(|v| *v = if *v > 0.0 { *v } else { v.exp_m1() }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gemm_identity() {
        // h @ I == h.
        let m = 3;
        let k = 4;
        let h: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
        let mut w = vec![0f32; k * k];
        for i in 0..k {
            w[i * k + i] = 1.0;
        }
        let out = gemm_bias_act(&h, m, k, &w, k, &vec![0.0; k], Activation::None);
        assert_eq!(out, h);
    }

    #[test]
    fn gemm_bias_and_relu() {
        let h = vec![1.0, -1.0];
        let w = vec![2.0, -2.0]; // 2x1... wait: k=2, n=1
        let out = gemm_bias_act(&h, 1, 2, &w, 1, &[-1.0], Activation::Relu);
        // 1*2 + (-1)(-2) - 1 = 3 -> relu 3.
        assert_eq!(out, vec![3.0]);
        let out2 = gemm_bias_act(&h, 1, 2, &w, 1, &[-5.0], Activation::Relu);
        assert_eq!(out2, vec![0.0]);
    }

    #[test]
    fn spdmm_sum_ring() {
        // Ring 0->1->2->3->0, unit weights, scalar features = id.
        let src = [0u32, 1, 2, 3];
        let dst = [1u32, 2, 3, 0];
        let ew = [1f32; 4];
        let h = [10f32, 11., 12., 13.];
        let out = spdmm(&src, &dst, &ew, &h, 1, 4, AggOp::Sum);
        assert_eq!(out, vec![13.0, 10.0, 11.0, 12.0]);
    }

    #[test]
    fn spdmm_max_untouched_is_zero() {
        let src = [0u32];
        let dst = [1u32];
        let out = spdmm(&src, &dst, &[2.0], &[3.0, 4.0], 1, 3, AggOp::Max);
        assert_eq!(out, vec![0.0, 6.0, 0.0]);
    }

    #[test]
    fn sddmm_inner_products() {
        let h = [1f32, 2., 3., 4.]; // 2 rows x 2
        let out = sddmm(&[0, 1], &[1, 1], &h, &h, 2);
        assert_eq!(out, vec![1. * 3. + 2. * 4., 3. * 3. + 4. * 4.]);
    }

    #[test]
    fn vecadd_relu() {
        let out = vecadd(&[1.0, -3.0], &[1.0, 1.0], Activation::Relu);
        assert_eq!(out, vec![2.0, 0.0]);
    }

    #[test]
    fn combine_partials_matches_single_pass_sum() {
        // Sum combine over zero-filled partials is exact for any data.
        let mut rng = Rng::new(5);
        let n = 32;
        let f = 8;
        let e = 200;
        let src: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let dst: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let ew: Vec<f32> = (0..e).map(|_| rng.normal()).collect();
        let h: Vec<f32> = (0..n * f).map(|_| rng.normal()).collect();
        let whole = spdmm(&src, &dst, &ew, &h, f, n, AggOp::Sum);
        let mid = e / 2;
        let mut acc = spdmm(&src[..mid], &dst[..mid], &ew[..mid], &h, f, n, AggOp::Sum);
        let part = spdmm(&src[mid..], &dst[mid..], &ew[mid..], &h, f, n, AggOp::Sum);
        combine_partials(&mut acc, &part, AggOp::Sum);
        for (a, w) in acc.iter().zip(&whole) {
            assert!((a - w).abs() < 1e-4, "{a} vs {w}");
        }
    }

    #[test]
    fn combine_partials_max_nonnegative() {
        // Max combine over zero-filled partials is exact when every
        // message is >= 0 (the touched-row masking for the general case
        // lives in exec::functional and is tested there).
        let mut rng = Rng::new(6);
        let n = 16;
        let f = 4;
        let e = 120;
        let src: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let dst: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let ew: Vec<f32> = (0..e).map(|_| rng.f32()).collect();
        let h: Vec<f32> = (0..n * f).map(|_| rng.f32()).collect();
        let whole = spdmm(&src, &dst, &ew, &h, f, n, AggOp::Max);
        let mid = e / 2;
        let mut acc = spdmm(&src[..mid], &dst[..mid], &ew[..mid], &h, f, n, AggOp::Max);
        let part = spdmm(&src[mid..], &dst[mid..], &ew[mid..], &h, f, n, AggOp::Max);
        combine_partials(&mut acc, &part, AggOp::Max);
        for (a, w) in acc.iter().zip(&whole) {
            assert!((a - w).abs() < 1e-5, "{a} vs {w}");
        }
    }

    #[test]
    fn activations_pointwise() {
        let mut x = vec![-1.0f32, 0.0, 2.0];
        apply_act(&mut x, Activation::Elu);
        assert!((x[0] - (-0.6321206)).abs() < 1e-5);
        assert_eq!(x[1], 0.0);
        assert_eq!(x[2], 2.0);
    }
}
