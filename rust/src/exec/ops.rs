//! Operator entry points on row-major `f32` buffers — the rust analogue
//! of the pure-jnp oracle (`python/compile/kernels/ref.py`). These back
//! the golden executor, the `RustBackend` tile executor, and the
//! naive-CPU baseline measurements.
//!
//! The top-level functions route through the optimized kernel backend
//! (`exec::kernels`: blocked GEMM, destination-row CSR aggregation,
//! row-block parallelism). The original scalar COO triple-loops are
//! kept verbatim in [`reference`] as the measurable baseline — property
//! tests (`rust/tests/kernel_backend.rs`) pin optimized against
//! reference across random shapes, and `cargo bench --bench
//! kernel_backend` records the speedup in `BENCH_kernels.json`.

use super::kernels;
use crate::isa::{Activation, AggOp};

/// out(m x n) = h(m x k) @ w(k x n) + b, then activation. Blocked and
/// row-parallel; packs nothing (one-shot calls — the tile executor uses
/// per-executable [`kernels::PackedWeights`] instead).
pub fn gemm_bias_act(
    h: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    b: &[f32],
    act: Activation,
) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    kernels::gemm_into(h, m, k, w, n, b, &mut out);
    apply_act(&mut out, act);
    out
}

/// Edge-centric SpDMM: out(n_out x f) = AggOp over edges (src, dst, w)
/// of w * h[src]; `src`/`dst` index into `h` rows / `out` rows.
/// Converts the COO stream to destination-row CSR once, then reduces
/// per output row. Untouched vertices produce 0 (the kernel/ref
/// convention), tracked through per-row touched flags — not the old
/// full-output `!is_finite` scan, which re-scanned the whole tile and
/// clobbered rows whose legitimate Max/Min aggregate is non-finite.
pub fn spdmm(
    src: &[u32],
    dst: &[u32],
    ew: &[f32],
    h: &[f32],
    f: usize,
    n_out: usize,
    aggop: AggOp,
) -> Vec<f32> {
    let init = match aggop {
        AggOp::Sum | AggOp::Mean => 0.0f32,
        AggOp::Max => f32::NEG_INFINITY,
        AggOp::Min => f32::INFINITY,
    };
    let csr = kernels::csr_from_coo(src, dst, n_out);
    let mut out = vec![init; n_out * f];
    let mut touched = vec![0u32; n_out];
    kernels::spdmm_csr_into(&csr, ew, h, f, aggop, &mut out, &mut touched);
    if init != 0.0 {
        for (r, &t) in touched.iter().enumerate() {
            if t == 0 {
                out[r * f..(r + 1) * f].fill(0.0);
            }
        }
    }
    out
}

/// Combine two partial aggregation tiles in place (cross-subshard).
pub fn combine_partials(acc: &mut [f32], part: &[f32], aggop: AggOp) {
    assert_eq!(acc.len(), part.len());
    match aggop {
        AggOp::Sum | AggOp::Mean => {
            for (a, &p) in acc.iter_mut().zip(part) {
                *a += p;
            }
        }
        AggOp::Max => {
            for (a, &p) in acc.iter_mut().zip(part) {
                *a = a.max(p);
            }
        }
        AggOp::Min => {
            for (a, &p) in acc.iter_mut().zip(part) {
                *a = a.min(p);
            }
        }
    }
}

/// SDDMM: per-edge inner products of rows of `hl` and `hr`. Rows are
/// grouped by destination (CSR) so each `hr` row is loaded once per
/// vertex, then results scatter back to edge order.
pub fn sddmm(src: &[u32], dst: &[u32], hl: &[f32], hr: &[f32], f: usize) -> Vec<f32> {
    if f == 0 || src.is_empty() {
        return vec![0f32; src.len()];
    }
    let n_out = hr.len() / f;
    let csr = kernels::csr_from_coo(src, dst, n_out);
    let mut vals = vec![0f32; src.len()];
    kernels::sddmm_csr_into(&csr, hl, hr, f, &mut vals);
    let mut out = vec![0f32; src.len()];
    for (slot, &v) in vals.iter().enumerate() {
        out[csr.perm[slot] as usize] = v;
    }
    out
}

/// Elementwise a + b with fused activation.
pub fn vecadd(a: &[f32], b: &[f32], act: Activation) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    let mut out: Vec<f32> = a.iter().zip(b).map(|(&x, &y)| x + y).collect();
    apply_act(&mut out, act);
    out
}

/// In-place activation (matches `ref.py::apply_act_ref` semantics).
pub fn apply_act(x: &mut [f32], act: Activation) {
    match act {
        Activation::None => {}
        Activation::Relu => x.iter_mut().for_each(|v| *v = v.max(0.0)),
        Activation::LRelu => x
            .iter_mut()
            .for_each(|v| *v = if *v > 0.0 { *v } else { 0.01 * *v }),
        Activation::PRelu => x
            .iter_mut()
            .for_each(|v| *v = if *v > 0.0 { *v } else { 0.25 * *v }),
        Activation::Swish => x.iter_mut().for_each(|v| {
            *v = *v / (1.0 + (-*v).exp());
        }),
        Activation::Exp => x.iter_mut().for_each(|v| *v = v.exp()),
        Activation::Sigmoid => x.iter_mut().for_each(|v| *v = 1.0 / (1.0 + (-*v).exp())),
        Activation::Elu => x
            .iter_mut()
            .for_each(|v| *v = if *v > 0.0 { *v } else { v.exp_m1() }),
    }
}

/// The original naive scalar kernels, kept as the measurable baseline:
/// triple loops over the COO edge list that allocate a fresh output per
/// call and ignore the cache hierarchy. Do not "optimize" these — their
/// whole value is being the fixed reference point for the equivalence
/// property tests and `BENCH_kernels.json`.
pub mod reference {
    use super::apply_act;
    use crate::isa::{Activation, AggOp};

    /// Naive i-k-j GEMM: out = h @ w + b, then activation.
    pub fn gemm_bias_act(
        h: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        b: &[f32],
        act: Activation,
    ) -> Vec<f32> {
        assert_eq!(h.len(), m * k, "h shape");
        assert_eq!(w.len(), k * n, "w shape");
        assert_eq!(b.len(), n, "bias shape");
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            let hrow = &h[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            orow.copy_from_slice(b);
            for (kk, &hv) in hrow.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * n..(kk + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += hv * wv;
                }
            }
        }
        apply_act(&mut out, act);
        out
    }

    /// Naive edge-centric SpDMM: random scatter over the COO stream.
    /// (The untouched-vertex cleanup uses a touched bitmap — the one
    /// correctness fix applied to the baseline, since the old
    /// `!is_finite` scan clobbered legitimate non-finite aggregates.)
    pub fn spdmm(
        src: &[u32],
        dst: &[u32],
        ew: &[f32],
        h: &[f32],
        f: usize,
        n_out: usize,
        aggop: AggOp,
    ) -> Vec<f32> {
        let init = match aggop {
            AggOp::Sum | AggOp::Mean => 0.0f32,
            AggOp::Max => f32::NEG_INFINITY,
            AggOp::Min => f32::INFINITY,
        };
        let mut out = vec![init; n_out * f];
        for ((&s, &d), &w) in src.iter().zip(dst).zip(ew) {
            let hrow = &h[s as usize * f..(s as usize + 1) * f];
            let orow = &mut out[d as usize * f..(d as usize + 1) * f];
            match aggop {
                AggOp::Sum | AggOp::Mean => {
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o += w * hv;
                    }
                }
                AggOp::Max => {
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o = o.max(w * hv);
                    }
                }
                AggOp::Min => {
                    for (o, &hv) in orow.iter_mut().zip(hrow) {
                        *o = o.min(w * hv);
                    }
                }
            }
        }
        // Untouched vertices produce 0 (matching the kernel/ref
        // convention).
        if init != 0.0 {
            let mut touched = vec![false; n_out];
            for &d in dst {
                touched[d as usize] = true;
            }
            for (r, &t) in touched.iter().enumerate() {
                if !t {
                    out[r * f..(r + 1) * f].fill(0.0);
                }
            }
        }
        out
    }

    /// Naive SDDMM: per-edge inner products in edge order.
    pub fn sddmm(src: &[u32], dst: &[u32], hl: &[f32], hr: &[f32], f: usize) -> Vec<f32> {
        src.iter()
            .zip(dst)
            .map(|(&s, &d)| {
                let a = &hl[s as usize * f..(s as usize + 1) * f];
                let b = &hr[d as usize * f..(d as usize + 1) * f];
                a.iter().zip(b).map(|(&x, &y)| x * y).sum()
            })
            .collect()
    }

    /// Elementwise a + b with fused activation.
    pub fn vecadd(a: &[f32], b: &[f32], act: Activation) -> Vec<f32> {
        assert_eq!(a.len(), b.len());
        let mut out: Vec<f32> = a.iter().zip(b).map(|(&x, &y)| x + y).collect();
        apply_act(&mut out, act);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gemm_identity() {
        // h @ I == h.
        let m = 3;
        let k = 4;
        let h: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
        let mut w = vec![0f32; k * k];
        for i in 0..k {
            w[i * k + i] = 1.0;
        }
        let out = gemm_bias_act(&h, m, k, &w, k, &vec![0.0; k], Activation::None);
        assert_eq!(out, h);
        let naive = reference::gemm_bias_act(&h, m, k, &w, k, &vec![0.0; k], Activation::None);
        assert_eq!(naive, h);
    }

    #[test]
    fn gemm_bias_and_relu() {
        let h = vec![1.0, -1.0];
        let w = vec![2.0, -2.0]; // k=2, n=1
        let out = gemm_bias_act(&h, 1, 2, &w, 1, &[-1.0], Activation::Relu);
        // 1*2 + (-1)(-2) - 1 = 3 -> relu 3.
        assert_eq!(out, vec![3.0]);
        let out2 = gemm_bias_act(&h, 1, 2, &w, 1, &[-5.0], Activation::Relu);
        assert_eq!(out2, vec![0.0]);
    }

    #[test]
    fn spdmm_sum_ring() {
        // Ring 0->1->2->3->0, unit weights, scalar features = id.
        let src = [0u32, 1, 2, 3];
        let dst = [1u32, 2, 3, 0];
        let ew = [1f32; 4];
        let h = [10f32, 11., 12., 13.];
        let out = spdmm(&src, &dst, &ew, &h, 1, 4, AggOp::Sum);
        assert_eq!(out, vec![13.0, 10.0, 11.0, 12.0]);
        assert_eq!(out, reference::spdmm(&src, &dst, &ew, &h, 1, 4, AggOp::Sum));
    }

    #[test]
    fn spdmm_max_untouched_is_zero() {
        let src = [0u32];
        let dst = [1u32];
        let out = spdmm(&src, &dst, &[2.0], &[3.0, 4.0], 1, 3, AggOp::Max);
        assert_eq!(out, vec![0.0, 6.0, 0.0]);
    }

    #[test]
    fn spdmm_touched_nonfinite_aggregate_survives() {
        // The satellite fix, on both kernels: a *touched* row whose
        // legitimate aggregate overflows to +inf must keep it — the old
        // full-output `!is_finite` scan zeroed it like an untouched row.
        let src = [0u32];
        let dst = [1u32];
        let h = [f32::MAX, 0.0, 0.0];
        for out in [
            spdmm(&src, &dst, &[4.0], &h, 1, 3, AggOp::Max),
            reference::spdmm(&src, &dst, &[4.0], &h, 1, 3, AggOp::Max),
        ] {
            assert_eq!(out[0], 0.0);
            assert!(out[1].is_infinite() && out[1] > 0.0, "clobbered: {}", out[1]);
            assert_eq!(out[2], 0.0);
        }
    }

    #[test]
    fn sddmm_inner_products() {
        let h = [1f32, 2., 3., 4.]; // 2 rows x 2
        let out = sddmm(&[0, 1], &[1, 1], &h, &h, 2);
        assert_eq!(out, vec![1. * 3. + 2. * 4., 3. * 3. + 4. * 4.]);
        assert_eq!(out, reference::sddmm(&[0, 1], &[1, 1], &h, &h, 2));
    }

    #[test]
    fn vecadd_relu() {
        let out = vecadd(&[1.0, -3.0], &[1.0, 1.0], Activation::Relu);
        assert_eq!(out, vec![2.0, 0.0]);
    }

    #[test]
    fn combine_partials_matches_single_pass_sum() {
        // Sum combine over zero-filled partials is exact for any data.
        let mut rng = Rng::new(5);
        let n = 32;
        let f = 8;
        let e = 200;
        let src: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let dst: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let ew: Vec<f32> = (0..e).map(|_| rng.normal()).collect();
        let h: Vec<f32> = (0..n * f).map(|_| rng.normal()).collect();
        let whole = spdmm(&src, &dst, &ew, &h, f, n, AggOp::Sum);
        let mid = e / 2;
        let mut acc = spdmm(&src[..mid], &dst[..mid], &ew[..mid], &h, f, n, AggOp::Sum);
        let part = spdmm(&src[mid..], &dst[mid..], &ew[mid..], &h, f, n, AggOp::Sum);
        combine_partials(&mut acc, &part, AggOp::Sum);
        for (a, w) in acc.iter().zip(&whole) {
            assert!((a - w).abs() < 1e-4, "{a} vs {w}");
        }
    }

    #[test]
    fn combine_partials_max_nonnegative() {
        // Max combine over zero-filled partials is exact when every
        // message is >= 0 (the touched-row masking for the general case
        // lives in exec::functional and is tested there).
        let mut rng = Rng::new(6);
        let n = 16;
        let f = 4;
        let e = 120;
        let src: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let dst: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let ew: Vec<f32> = (0..e).map(|_| rng.f32()).collect();
        let h: Vec<f32> = (0..n * f).map(|_| rng.f32()).collect();
        let whole = spdmm(&src, &dst, &ew, &h, f, n, AggOp::Max);
        let mid = e / 2;
        let mut acc = spdmm(&src[..mid], &dst[..mid], &ew[..mid], &h, f, n, AggOp::Max);
        let part = spdmm(&src[mid..], &dst[mid..], &ew[mid..], &h, f, n, AggOp::Max);
        combine_partials(&mut acc, &part, AggOp::Max);
        for (a, w) in acc.iter().zip(&whole) {
            assert!((a - w).abs() < 1e-5, "{a} vs {w}");
        }
    }

    #[test]
    fn activations_pointwise() {
        let mut x = vec![-1.0f32, 0.0, 2.0];
        apply_act(&mut x, Activation::Elu);
        assert!((x[0] - (-0.6321206)).abs() < 1e-5);
        assert_eq!(x[1], 0.0);
        assert_eq!(x[2], 2.0);
    }

    #[test]
    fn optimized_matches_reference_randomized() {
        // Smoke-level pin (the full property suite lives in
        // rust/tests/kernel_backend.rs).
        let mut rng = Rng::new(31);
        let (n, f, e) = (40usize, 24usize, 300usize);
        let src: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let dst: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let ew: Vec<f32> = (0..e).map(|_| rng.normal()).collect();
        let h: Vec<f32> = (0..n * f).map(|_| rng.normal()).collect();
        for agg in [AggOp::Sum, AggOp::Mean, AggOp::Max, AggOp::Min] {
            let a = spdmm(&src, &dst, &ew, &h, f, n, agg);
            let b = reference::spdmm(&src, &dst, &ew, &h, f, n, agg);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{agg:?}: {x} vs {y}");
            }
        }
        let a = sddmm(&src, &dst, &h, &h, f);
        let b = reference::sddmm(&src, &dst, &h, &h, f);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "sddmm: {x} vs {y}");
        }
    }
}
