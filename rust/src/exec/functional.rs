//! Partition-centric functional executor: runs the compiler's Tiling
//! Blocks (the [`TileTask`] view of the `.ga` program) over real graph
//! data, tile by tile, through a pluggable [`TileBackend`].
//!
//! Backends:
//! * [`RustBackend`] — the reference operators (`exec::ops`);
//! * `runtime::PjrtBackend` — the AOT-compiled HLO kernels (Pallas L1 /
//!   JAX L2) executed on the PJRT CPU client.
//!
//! Executing the *same* compiled schedule through both and matching the
//! golden whole-graph result proves the compiler's partitioning, kernel
//! mapping, and the L1 kernels compose functionally (DESIGN.md Sec. 5).

use super::golden::WeightStore;
use super::ops;
use crate::compiler::{Executable, TileTask};
use crate::graph::PartitionedGraph;
use crate::ir::LayerType;
use crate::isa::{Activation, AggOp};
use crate::sparsity::{choose_mode, tile_density, KernelMode};
use std::collections::HashMap;

/// Tile-granular compute abstraction. Index arguments are tile-local.
pub trait TileBackend {
    fn name(&self) -> &'static str;

    /// out(m x n) = h(m x k) @ w(k x n) + b (no activation — the
    /// executor applies fused activations after tile assembly).
    fn gemm(&mut self, h: &[f32], m: usize, k: usize, w: &[f32], n: usize, b: &[f32])
        -> Vec<f32>;

    /// Edge-centric aggregate over one subshard: returns an
    /// (n_out x f) partial (untouched rows are 0).
    #[allow(clippy::too_many_arguments)]
    fn spdmm(
        &mut self,
        src: &[u32],
        dst: &[u32],
        ew: &[f32],
        h: &[f32],
        n_in: usize,
        f: usize,
        n_out: usize,
        aggop: AggOp,
    ) -> Vec<f32>;

    /// Per-edge inner products `<hl[src], hr[dst]>`.
    #[allow(clippy::too_many_arguments)]
    fn sddmm(
        &mut self,
        src: &[u32],
        dst: &[u32],
        hl: &[f32],
        hr: &[f32],
        n_l: usize,
        n_r: usize,
        f: usize,
    ) -> Vec<f32>;

    /// Elementwise a + b.
    fn vecadd(&mut self, a: &[f32], b: &[f32]) -> Vec<f32>;
}

/// Pure-rust backend: directly the reference operators.
#[derive(Default)]
pub struct RustBackend;

impl TileBackend for RustBackend {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn gemm(&mut self, h: &[f32], m: usize, k: usize, w: &[f32], n: usize, b: &[f32])
        -> Vec<f32> {
        ops::gemm_bias_act(h, m, k, w, n, b, Activation::None)
    }

    fn spdmm(
        &mut self,
        src: &[u32],
        dst: &[u32],
        ew: &[f32],
        h: &[f32],
        _n_in: usize,
        f: usize,
        n_out: usize,
        aggop: AggOp,
    ) -> Vec<f32> {
        ops::spdmm(src, dst, ew, h, f, n_out, aggop)
    }

    fn sddmm(
        &mut self,
        src: &[u32],
        dst: &[u32],
        hl: &[f32],
        hr: &[f32],
        _n_l: usize,
        _n_r: usize,
        f: usize,
    ) -> Vec<f32> {
        ops::sddmm(src, dst, hl, hr, f)
    }

    fn vecadd(&mut self, a: &[f32], b: &[f32]) -> Vec<f32> {
        ops::vecadd(a, b, Activation::None)
    }
}

/// Wraps any backend and counts kernel launches and bytes streamed
/// through tile arguments/results — the engine layer's per-run profile
/// (the hardware analogue is the scheduler's dispatch counter).
pub struct CountingBackend<B: TileBackend> {
    pub inner: B,
    pub launches: u64,
    pub bytes: u64,
}

impl<B: TileBackend> CountingBackend<B> {
    pub fn new(inner: B) -> Self {
        CountingBackend { inner, launches: 0, bytes: 0 }
    }
}

impl<B: TileBackend> TileBackend for CountingBackend<B> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn gemm(&mut self, h: &[f32], m: usize, k: usize, w: &[f32], n: usize, b: &[f32])
        -> Vec<f32> {
        self.launches += 1;
        let out = self.inner.gemm(h, m, k, w, n, b);
        self.bytes += 4 * (h.len() + w.len() + b.len() + out.len()) as u64;
        out
    }

    fn spdmm(
        &mut self,
        src: &[u32],
        dst: &[u32],
        ew: &[f32],
        h: &[f32],
        n_in: usize,
        f: usize,
        n_out: usize,
        aggop: AggOp,
    ) -> Vec<f32> {
        self.launches += 1;
        let out = self.inner.spdmm(src, dst, ew, h, n_in, f, n_out, aggop);
        self.bytes += 4 * (src.len() + dst.len() + ew.len() + h.len() + out.len()) as u64;
        out
    }

    fn sddmm(
        &mut self,
        src: &[u32],
        dst: &[u32],
        hl: &[f32],
        hr: &[f32],
        n_l: usize,
        n_r: usize,
        f: usize,
    ) -> Vec<f32> {
        self.launches += 1;
        let out = self.inner.sddmm(src, dst, hl, hr, n_l, n_r, f);
        self.bytes += 4 * (src.len() + dst.len() + hl.len() + hr.len() + out.len()) as u64;
        out
    }

    fn vecadd(&mut self, a: &[f32], b: &[f32]) -> Vec<f32> {
        self.launches += 1;
        let out = self.inner.vecadd(a, b);
        self.bytes += 4 * (a.len() + b.len() + out.len()) as u64;
        out
    }
}

/// Copy a (rows x cols) sub-tile out of a row-major (n x f) buffer.
pub fn slice_tile(
    buf: &[f32],
    f: usize,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * cols);
    for r in row0..row0 + rows {
        out.extend_from_slice(&buf[r * f + col0..r * f + col0 + cols]);
    }
    out
}

/// Write a (rows x cols) sub-tile into a row-major (n x f) buffer.
pub fn write_tile(
    buf: &mut [f32],
    f: usize,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    tile: &[f32],
) {
    debug_assert_eq!(tile.len(), rows * cols);
    for r in 0..rows {
        buf[(row0 + r) * f + col0..(row0 + r) * f + col0 + cols]
            .copy_from_slice(&tile[r * cols..(r + 1) * cols]);
    }
}

/// The executor. Holds the compiled program, the partition-ordered graph
/// and the weights; `run` produces the final feature matrix.
///
/// With `dynamic` set, the executor consults the executable's density
/// threshold table (the GA02 section) per subshard and re-maps
/// dense-enough Sum/Mean aggregations from the SpDMM path onto the GEMM
/// path — a densified adjacency tile times the feature subfiber, the
/// exact weighted sum the edge stream computes — so results are
/// bit-equivalent up to float summation order.
pub struct FunctionalExecutor<'a, B: TileBackend> {
    pub exe: &'a Executable,
    pub graph: &'a PartitionedGraph,
    pub store: &'a WeightStore,
    pub backend: B,
    /// Density-aware dynamic kernel re-mapping on/off.
    pub dynamic: bool,
    /// Subshard tasks executed on a re-mapped kernel this run.
    pub remaps: u64,
}

impl<'a, B: TileBackend> FunctionalExecutor<'a, B> {
    pub fn new(
        exe: &'a Executable,
        graph: &'a PartitionedGraph,
        store: &'a WeightStore,
        backend: B,
    ) -> Self {
        assert_eq!(
            exe.cfg.n1, graph.cfg.n1,
            "graph partitioned with a different N1 than the executable"
        );
        FunctionalExecutor { exe, graph, store, backend, dynamic: false, remaps: 0 }
    }

    /// Execute every Tiling Block in program order. Returns the last
    /// layer's output (n x f_out).
    pub fn run(&mut self, x: &[f32]) -> Vec<f32> {
        let n = self.graph.n_vertices as usize;
        let n1 = self.exe.cfg.n1 as usize;
        let ir = &self.exe.ir;
        let f0 = ir.graph.feat_len as usize;
        assert_eq!(x.len(), n * f0);
        let mut outputs: HashMap<u16, Vec<f32>> = HashMap::new();
        let mut fdims: HashMap<u16, usize> = HashMap::new();
        let mut edge_w: Vec<f32> = self.graph.w.clone();
        let mut last = 0u16;
        for (layer, tasks) in ir.layers.iter().zip(&self.exe.tasks) {
            debug_assert_eq!(layer.id, tasks.layer_id);
            let f_in = layer.f_in as usize;
            let f_out = layer.f_out as usize;
            let input = |pid: Option<&u16>,
                         outputs: &HashMap<u16, Vec<f32>>|
             -> Vec<f32> {
                match pid {
                    Some(p) => outputs.get(p).expect("parent not computed").clone(),
                    None => x.to_vec(),
                }
            };
            let h_in = input(layer.parents.first(), &outputs);
            let mut out = vec![0f32; n * f_out];
            match layer.ltype {
                LayerType::Aggregate => {
                    // Re-map inputs are per layer: hoist the threshold
                    // table and this layer's provisional mode out of the
                    // per-subshard loop (mirrors sim::engine).
                    let remap_tt =
                        if self.dynamic { self.exe.program.thresholds.as_ref() } else { None };
                    let provisional = remap_tt
                        .and_then(|tt| tt.entry(layer.id))
                        .map(|e| e.provisional)
                        .unwrap_or(KernelMode::Spdmm);
                    for t in &tasks.tasks {
                        let TileTask::Aggregate {
                            fiber, shard, rows, cols, aggop, act, subshards,
                        } = t
                        else {
                            panic!("task/layer type mismatch")
                        };
                        let (rows, cols) = (*rows as usize, *cols as usize);
                        let (row0, col0) =
                            (*shard as usize * n1, *fiber as usize * self.exe.cfg.n2 as usize);
                        let neutral = match aggop {
                            AggOp::Sum | AggOp::Mean => 0.0f32,
                            AggOp::Max => f32::NEG_INFINITY,
                            AggOp::Min => f32::INFINITY,
                        };
                        let mut acc = vec![neutral; rows * cols];
                        let mut touched = vec![false; rows];
                        for sref in subshards {
                            let k = sref.k as usize;
                            let range = self.graph.subshard(*shard as usize, k);
                            if range.is_empty() {
                                continue;
                            }
                            let src: Vec<u32> = self.graph.src[range.clone()]
                                .iter()
                                .map(|&s| s - (k * n1) as u32)
                                .collect();
                            let dst: Vec<u32> = self.graph.dst[range.clone()]
                                .iter()
                                .map(|&d| d - row0 as u32)
                                .collect();
                            let ew = &edge_w[range.clone()];
                            let rows_k = (n - k * n1).min(n1);
                            let h_tile = slice_tile(&h_in, f_in, k * n1, rows_k, col0, cols);
                            // Dynamic re-map: a dense-enough Sum/Mean
                            // subshard runs as a densified-adjacency GEMM
                            // (the same weighted sum, computed on the
                            // dense path the ACK would be re-mapped to).
                            // Max/Min are not a matmul — never re-mapped.
                            let dense_mode = matches!(aggop, AggOp::Sum | AggOp::Mean)
                                && remap_tt.is_some_and(|tt| {
                                    let d = tile_density(
                                        sref.ne,
                                        rows as u64,
                                        rows_k as u64,
                                    );
                                    choose_mode(provisional, d, tt) == KernelMode::Gemm
                                });
                            let part = if dense_mode {
                                self.remaps += 1;
                                let mut a = vec![0f32; rows * rows_k];
                                for ((&s, &d), &w) in src.iter().zip(&dst).zip(ew) {
                                    a[d as usize * rows_k + s as usize] += w;
                                }
                                self.backend.gemm(
                                    &a,
                                    rows,
                                    rows_k,
                                    &h_tile,
                                    cols,
                                    &vec![0f32; cols],
                                )
                            } else {
                                self.backend.spdmm(
                                    &src, &dst, ew, &h_tile, rows_k, cols, rows, *aggop,
                                )
                            };
                            // Cross-subshard combine on touched rows only
                            // (the hardware accumulates in-place in the
                            // Feature Buffer; partials have 0 padding).
                            for &d in &dst {
                                touched[d as usize] = true;
                            }
                            match aggop {
                                AggOp::Sum | AggOp::Mean => {
                                    for (a, &p) in acc.iter_mut().zip(&part) {
                                        if *a == f32::NEG_INFINITY {
                                            *a = 0.0;
                                        }
                                        *a += p;
                                    }
                                }
                                AggOp::Max | AggOp::Min => {
                                    for r in 0..rows {
                                        if !dst.contains(&(r as u32)) {
                                            continue;
                                        }
                                        for c in 0..cols {
                                            let a = &mut acc[r * cols + c];
                                            let p = part[r * cols + c];
                                            *a = if *aggop == AggOp::Max {
                                                a.max(p)
                                            } else {
                                                a.min(p)
                                            };
                                        }
                                    }
                                }
                            }
                        }
                        // Untouched rows -> 0 (kernel convention).
                        for r in 0..rows {
                            if !touched[r] {
                                for c in 0..cols {
                                    acc[r * cols + c] = 0.0;
                                }
                            }
                        }
                        ops::apply_act(&mut acc, *act);
                        write_tile(&mut out, f_out, row0, rows, col0, cols, &acc);
                    }
                }
                LayerType::Linear => {
                    let (w, b) = self.store.get(layer.id);
                    for t in &tasks.tasks {
                        let TileTask::Linear { row0, rows, act, .. } = t else {
                            panic!("task/layer type mismatch")
                        };
                        let rows = *rows as usize;
                        let row0 = *row0 as usize;
                        let h_tile = slice_tile(&h_in, f_in, row0, rows, 0, f_in);
                        let mut o = self.backend.gemm(&h_tile, rows, f_in, w, f_out, b);
                        ops::apply_act(&mut o, *act);
                        write_tile(&mut out, f_out, row0, rows, 0, f_out, &o);
                    }
                }
                LayerType::VectorInner => {
                    for t in &tasks.tasks {
                        let TileTask::VectorInner { i, j, ne, act, .. } = t else {
                            panic!("task/layer type mismatch")
                        };
                        if *ne == 0 {
                            continue;
                        }
                        let range = self.graph.subshard(*i as usize, *j as usize);
                        debug_assert_eq!(range.len() as u64, *ne);
                        let rows_j = (n - *j as usize * n1).min(n1);
                        let rows_i = (n - *i as usize * n1).min(n1);
                        let src: Vec<u32> = self.graph.src[range.clone()]
                            .iter()
                            .map(|&s| s - (*j as usize * n1) as u32)
                            .collect();
                        let dst: Vec<u32> = self.graph.dst[range.clone()]
                            .iter()
                            .map(|&d| d - (*i as usize * n1) as u32)
                            .collect();
                        let hl = slice_tile(&h_in, f_in, *j as usize * n1, rows_j, 0, f_in);
                        let hr = slice_tile(&h_in, f_in, *i as usize * n1, rows_i, 0, f_in);
                        let mut ew =
                            self.backend.sddmm(&src, &dst, &hl, &hr, rows_j, rows_i, f_in);
                        ops::apply_act(&mut ew, *act);
                        edge_w[range].copy_from_slice(&ew);
                    }
                    // Features pass through a Vector-Inner layer.
                    out = h_in.clone();
                }
                LayerType::VectorAdd => {
                    let h2 = input(layer.parents.get(1), &outputs);
                    for t in &tasks.tasks {
                        let TileTask::VectorAdd { fiber, shard, rows, cols, act } = t
                        else {
                            panic!("task/layer type mismatch")
                        };
                        let (rows, cols) = (*rows as usize, *cols as usize);
                        let (row0, col0) =
                            (*shard as usize * n1, *fiber as usize * self.exe.cfg.n2 as usize);
                        let a = slice_tile(&h_in, f_in, row0, rows, col0, cols);
                        let b2 = slice_tile(&h2, f_in, row0, rows, col0, cols);
                        let mut o = self.backend.vecadd(&a, &b2);
                        ops::apply_act(&mut o, *act);
                        write_tile(&mut out, f_out, row0, rows, col0, cols, &o);
                    }
                }
                LayerType::Activation | LayerType::BatchNorm => {
                    // Edge-score activation (parent is a Vector-Inner):
                    // acts on the edge-weight state, features pass through
                    // (mirrors golden_forward's semantics).
                    let edge_parent = layer
                        .parents
                        .first()
                        .map(|&p| {
                            ir.layers.iter().any(|q| {
                                q.id == p && q.ltype == LayerType::VectorInner
                            })
                        })
                        .unwrap_or(false);
                    if edge_parent && layer.ltype == LayerType::Activation {
                        ops::apply_act(&mut edge_w, layer.act);
                        outputs.insert(layer.id, h_in);
                        last = layer.id;
                        continue;
                    }
                    for t in &tasks.tasks {
                        let TileTask::Eltwise { fiber, shard, rows, cols, act, batchnorm } =
                            t
                        else {
                            panic!("task/layer type mismatch")
                        };
                        let (rows, cols) = (*rows as usize, *cols as usize);
                        let (row0, col0) =
                            (*shard as usize * n1, *fiber as usize * self.exe.cfg.n2 as usize);
                        let mut tile = slice_tile(&h_in, f_in, row0, rows, col0, cols);
                        if !batchnorm {
                            ops::apply_act(&mut tile, *act);
                        } // inference BN with unit scale: identity
                        write_tile(&mut out, f_out, row0, rows, col0, cols, &tile);
                    }
                }
            }
            outputs.insert(layer.id, out);
            fdims.insert(layer.id, f_out);
            last = layer.id;
        }
        outputs.remove(&last).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::config::HwConfig;
    use crate::exec::golden::golden_forward;
    use crate::graph::{rmat::rmat_edges, CooGraph, GraphMeta, PartitionConfig};
    use crate::ir::ZooModel;

    fn setup(
        model: ZooModel,
        n: u64,
        e: u64,
        f: u64,
    ) -> (Executable, PartitionedGraph, CooGraph, WeightStore) {
        let meta = GraphMeta::new("t", n, e, f, 4);
        let g = rmat_edges(meta, Default::default(), 9).gcn_normalized();
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        let tiles = pg.tile_counts();
        let ir = model.build(g.meta.clone());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        (exe, pg, g, store)
    }

    fn max_err(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn functional_matches_golden_multi_shard() {
        // 300 vertices at N1=128 -> 3 shards; exercises cross-subshard
        // accumulation and fiber splitting (f=64 < 64? use f=32: 1 fiber
        // at N2=64; use f=96 for 2 fibers).
        for model in [ZooModel::B1, ZooModel::B7] {
            let (exe, pg, g, store) = setup(model, 300, 1500, 32);
            let x = g.random_features(5);
            let golden = golden_forward(&exe.ir, &g, &store, &x);
            let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
            let got = fx.run(&x);
            let err = max_err(&golden, &got);
            assert!(err < 1e-3, "{}: max err {err}", exe.ir.name);
        }
    }

    #[test]
    fn functional_matches_golden_all_models() {
        for model in crate::ir::ALL_MODELS {
            let (exe, pg, g, store) = setup(model, 200, 800, 16);
            let x = g.random_features(6);
            let golden = golden_forward(&exe.ir, &g, &store, &x);
            let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
            let got = fx.run(&x);
            let err = max_err(&golden, &got);
            // b6/b8 exponentials amplify error; scale tolerance by output
            // magnitude.
            let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
            assert!(
                err <= 1e-3 * scale.max(1.0),
                "{}: max err {err} (scale {scale})",
                exe.ir.name
            );
        }
    }

    #[test]
    fn tile_slicing_roundtrip() {
        let n = 7;
        let f = 5;
        let buf: Vec<f32> = (0..n * f).map(|i| i as f32).collect();
        let tile = slice_tile(&buf, f, 2, 3, 1, 2);
        assert_eq!(tile.len(), 6);
        assert_eq!(tile[0], (2 * f + 1) as f32);
        let mut buf2 = vec![0f32; n * f];
        write_tile(&mut buf2, f, 2, 3, 1, 2, &tile);
        assert_eq!(buf2[2 * f + 1], tile[0]);
        assert_eq!(buf2[4 * f + 2], tile[5]);
    }

    #[test]
    fn max_aggregation_cross_shard() {
        // GraphGym point with Max aggregation over a multi-shard graph:
        // the touched-row combine logic must match the golden result.
        use crate::ir::GraphGymConfig;
        let meta = GraphMeta::new("t", 300, 2000, 16, 4);
        let g = rmat_edges(meta, Default::default(), 13);
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        let ggcfg = GraphGymConfig {
            aggop: crate::isa::AggOp::Max,
            n_mp: 2,
            hidden: 16,
            ..Default::default()
        };
        let ir = ggcfg.build("gg-max", g.meta.clone());
        let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 44);
        let x = g.random_features(7);
        let golden = golden_forward(&exe.ir, &g, &store, &x);
        let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let got = fx.run(&x);
        let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
        let err = max_err(&golden, &got);
        assert!(err <= 1e-3 * scale.max(1.0), "max-agg err {err}");
    }
}
