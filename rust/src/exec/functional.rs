//! Partition-centric functional executor: runs the compiler's Tiling
//! Blocks (the [`TileTask`] view of the `.ga` program) over real graph
//! data, tile by tile, through a pluggable [`TileBackend`].
//!
//! Backends:
//! * [`RustBackend`] — the optimized kernel backend (`exec::kernels`):
//!   blocked GEMM over per-executable packed weights, destination-row
//!   CSR aggregation, row-block parallelism;
//! * [`ReferenceBackend`] — the naive scalar COO kernels
//!   (`ops::reference`), kept as the measurable baseline;
//! * `runtime::PjrtBackend` — the AOT-compiled HLO kernels (Pallas L1 /
//!   JAX L2) executed on the PJRT CPU client.
//!
//! The executor itself is allocation-free in steady state: every tile
//! buffer (feature slices, accumulators, per-edge values, layer
//! outputs) is drawn from and recycled into a [`BufferArena`], kernels
//! write into caller-provided buffers, and subshard aggregation
//! accumulates *in place* over the prebuilt
//! [`crate::graph::CsrSubshard`] index — no per-subshard partial
//! matrices, no per-subshard `src`/`dst` index rebuilds. After a warm
//! run, the only fresh allocation per inference is the output matrix
//! that escapes to the caller (asserted in
//! `rust/tests/kernel_backend.rs`).
//!
//! Executing the *same* compiled schedule through both rust backends
//! and the PJRT path and matching the golden whole-graph result proves
//! the compiler's partitioning, kernel mapping, and the kernels compose
//! functionally (DESIGN.md Sec. 5).
//!
//! **Quantized execution** (DESIGN.md Sec. 3f): when the executable's
//! program carries a GA03 [`crate::quant::ScaleTable`], layers with a
//! scale entry run on the int8 datapath — features quantized per tile,
//! weights pre-quantized into [`PackedWeightSetI8`] panels, i32
//! accumulation, and a dequantize epilogue fused with the layer
//! activation. Integer accumulation is exact, so quantized outputs are
//! bit-identical across thread counts and runs (pinned in
//! `rust/tests/quant.rs`). The int8 kernels are the optimized set
//! regardless of [`TileBackend`] — the backend still executes every
//! non-quantized layer.

use super::arena::BufferArena;
use super::golden::WeightStore;
use super::kernels::{self, PackedWeightSet, PackedWeightSetI8, PackedWeights};
use super::ops;
use crate::compiler::{Executable, TileTask};
use crate::graph::{CsrSubshard, PartitionedGraph};
use crate::ir::LayerType;
use crate::isa::{Activation, AggOp};
use crate::sparsity::{choose_mode, tile_density, KernelMode};
use std::collections::HashMap;

/// Tile-granular compute abstraction. Index arguments are tile-local;
/// every method writes into a caller-provided output buffer so the hot
/// loop allocates nothing.
pub trait TileBackend {
    fn name(&self) -> &'static str;

    /// out(m x n) = h(m x k) @ w(k x n) + b (no activation — the
    /// executor applies fused activations after tile assembly). `out`
    /// is fully overwritten.
    #[allow(clippy::too_many_arguments)]
    fn gemm(
        &mut self,
        h: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        b: &[f32],
        out: &mut [f32],
    );

    /// GEMM against weights packed once per executable. Backends
    /// without a packed kernel fall back to reconstructing the
    /// row-major view (an allocation — only the PJRT and reference
    /// backends take this path; the optimized backend consumes the
    /// panels directly).
    fn gemm_packed(&mut self, h: &[f32], m: usize, pw: &PackedWeights, b: &[f32], out: &mut [f32]) {
        let raw = pw.unpack();
        self.gemm(h, m, pw.k, &raw, pw.n, b, out);
    }

    /// Aggregate one CSR subshard *into* `acc` (rows x f), which
    /// arrives pre-initialized with the aggregation's neutral element
    /// (or earlier subshards' partials — in-place accumulation is the
    /// cross-subshard combine). Rows with edges are flagged in
    /// `touched`; the executor zeroes untouched Max/Min rows once per
    /// tile. Edge weights are gathered through `csr.perm`, keeping
    /// SDDMM-updated weights live.
    #[allow(clippy::too_many_arguments)]
    fn spdmm_csr(
        &mut self,
        csr: &CsrSubshard,
        ew: &[f32],
        h: &[f32],
        f: usize,
        aggop: AggOp,
        acc: &mut [f32],
        touched: &mut [u32],
    );

    /// Per-edge inner products in CSR slot order: vals[slot] =
    /// `<hl[csr.cols[slot]], hr[row(slot)]>`. The executor scatters
    /// `vals` back to edge order through `csr.perm`.
    fn sddmm_csr(&mut self, csr: &CsrSubshard, hl: &[f32], hr: &[f32], f: usize, vals: &mut [f32]);

    /// out = a + b elementwise.
    fn vecadd(&mut self, a: &[f32], b: &[f32], out: &mut [f32]);
}

/// Optimized pure-rust backend — directly the `exec::kernels` trio.
#[derive(Clone, Copy, Debug, Default)]
pub struct RustBackend;

impl TileBackend for RustBackend {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn gemm(
        &mut self,
        h: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        b: &[f32],
        out: &mut [f32],
    ) {
        kernels::gemm_into(h, m, k, w, n, b, out);
    }

    fn gemm_packed(&mut self, h: &[f32], m: usize, pw: &PackedWeights, b: &[f32], out: &mut [f32]) {
        kernels::gemm_packed_into(h, m, pw, b, out);
    }

    fn spdmm_csr(
        &mut self,
        csr: &CsrSubshard,
        ew: &[f32],
        h: &[f32],
        f: usize,
        aggop: AggOp,
        acc: &mut [f32],
        touched: &mut [u32],
    ) {
        kernels::spdmm_csr_into(csr, ew, h, f, aggop, acc, touched);
    }

    fn sddmm_csr(&mut self, csr: &CsrSubshard, hl: &[f32], hr: &[f32], f: usize, vals: &mut [f32]) {
        kernels::sddmm_csr_into(csr, hl, hr, f, vals);
    }

    fn vecadd(&mut self, a: &[f32], b: &[f32], out: &mut [f32]) {
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x + y;
        }
    }
}

/// The naive baseline backend: scalar COO triple loops
/// (`ops::reference`) that materialize per-subshard index arrays and
/// partial matrices per call — exactly the pre-optimization tile path,
/// kept callable so `BENCH_kernels.json` and the equivalence property
/// tests have a fixed reference point.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReferenceBackend;

impl ReferenceBackend {
    /// Rebuild the subshard's COO arrays (what the old executor did per
    /// tile): slot-ordered local src/dst plus gathered live weights.
    fn materialize_coo(csr: &CsrSubshard, ew: &[f32]) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
        let nnz = csr.nnz();
        let mut src = vec![0u32; nnz];
        let mut dst = vec![0u32; nnz];
        let mut w = vec![0f32; nnz];
        let mut at = 0;
        for r in 0..csr.rows as usize {
            for slot in csr.row(r) {
                src[at] = csr.cols[slot];
                dst[at] = r as u32;
                w[at] = ew[csr.perm[slot] as usize];
                at += 1;
            }
        }
        (src, dst, w)
    }
}

impl TileBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn gemm(
        &mut self,
        h: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        b: &[f32],
        out: &mut [f32],
    ) {
        out.copy_from_slice(&ops::reference::gemm_bias_act(h, m, k, w, n, b, Activation::None));
    }

    fn spdmm_csr(
        &mut self,
        csr: &CsrSubshard,
        ew: &[f32],
        h: &[f32],
        f: usize,
        aggop: AggOp,
        acc: &mut [f32],
        touched: &mut [u32],
    ) {
        let rows = csr.rows as usize;
        let (src, dst, w) = Self::materialize_coo(csr, ew);
        let part = ops::reference::spdmm(&src, &dst, &w, h, f, rows, aggop);
        match aggop {
            AggOp::Sum | AggOp::Mean => {
                for (a, &p) in acc.iter_mut().zip(&part) {
                    *a += p;
                }
            }
            AggOp::Max | AggOp::Min => {
                for r in 0..rows {
                    if csr.row(r).is_empty() {
                        continue;
                    }
                    for c in 0..f {
                        let a = &mut acc[r * f + c];
                        let p = part[r * f + c];
                        *a = if aggop == AggOp::Max { a.max(p) } else { a.min(p) };
                    }
                }
            }
        }
        for r in 0..rows {
            if !csr.row(r).is_empty() {
                touched[r] = 1;
            }
        }
    }

    fn sddmm_csr(&mut self, csr: &CsrSubshard, hl: &[f32], hr: &[f32], f: usize, vals: &mut [f32]) {
        let mut src = vec![0u32; csr.nnz()];
        let mut dst = vec![0u32; csr.nnz()];
        let mut at = 0;
        for r in 0..csr.rows as usize {
            for slot in csr.row(r) {
                src[at] = csr.cols[slot];
                dst[at] = r as u32;
                at += 1;
            }
        }
        // Materialized COO is in slot order, so the edge-order result
        // is already the slot-order result.
        vals.copy_from_slice(&ops::reference::sddmm(&src, &dst, hl, hr, f));
    }

    fn vecadd(&mut self, a: &[f32], b: &[f32], out: &mut [f32]) {
        out.copy_from_slice(&ops::reference::vecadd(a, b, Activation::None));
    }
}

/// Wraps any backend and counts kernel launches and bytes streamed
/// through tile arguments/results — the engine layer's per-run profile
/// (the hardware analogue is the scheduler's dispatch counter).
pub struct CountingBackend<B: TileBackend> {
    pub inner: B,
    pub launches: u64,
    pub bytes: u64,
}

impl<B: TileBackend> CountingBackend<B> {
    pub fn new(inner: B) -> Self {
        CountingBackend { inner, launches: 0, bytes: 0 }
    }
}

impl<B: TileBackend> TileBackend for CountingBackend<B> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn gemm(
        &mut self,
        h: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        b: &[f32],
        out: &mut [f32],
    ) {
        self.launches += 1;
        self.bytes += 4 * (h.len() + w.len() + b.len() + out.len()) as u64;
        self.inner.gemm(h, m, k, w, n, b, out);
    }

    fn gemm_packed(&mut self, h: &[f32], m: usize, pw: &PackedWeights, b: &[f32], out: &mut [f32]) {
        self.launches += 1;
        self.bytes += 4 * (h.len() + pw.k * pw.n + b.len() + out.len()) as u64;
        self.inner.gemm_packed(h, m, pw, b, out);
    }

    fn spdmm_csr(
        &mut self,
        csr: &CsrSubshard,
        ew: &[f32],
        h: &[f32],
        f: usize,
        aggop: AggOp,
        acc: &mut [f32],
        touched: &mut [u32],
    ) {
        self.launches += 1;
        self.bytes += 4 * (2 * csr.nnz() + ew.len() + h.len() + acc.len()) as u64;
        self.inner.spdmm_csr(csr, ew, h, f, aggop, acc, touched);
    }

    fn sddmm_csr(&mut self, csr: &CsrSubshard, hl: &[f32], hr: &[f32], f: usize, vals: &mut [f32]) {
        self.launches += 1;
        self.bytes += 4 * (2 * csr.nnz() + hl.len() + hr.len() + vals.len()) as u64;
        self.inner.sddmm_csr(csr, hl, hr, f, vals);
    }

    fn vecadd(&mut self, a: &[f32], b: &[f32], out: &mut [f32]) {
        self.launches += 1;
        self.bytes += 4 * (a.len() + b.len() + out.len()) as u64;
        self.inner.vecadd(a, b, out);
    }
}

/// Copy a (rows x cols) sub-tile out of a row-major (n x f) buffer.
pub fn slice_tile(
    buf: &[f32],
    f: usize,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * cols);
    for r in row0..row0 + rows {
        out.extend_from_slice(&buf[r * f + col0..r * f + col0 + cols]);
    }
    out
}

/// [`slice_tile`] into a caller-provided buffer (arena hot path).
pub fn slice_tile_into(
    buf: &[f32],
    f: usize,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * cols);
    for r in 0..rows {
        let at = (row0 + r) * f + col0;
        out[r * cols..(r + 1) * cols].copy_from_slice(&buf[at..at + cols]);
    }
}

/// Write a (rows x cols) sub-tile into a row-major (n x f) buffer.
pub fn write_tile(
    buf: &mut [f32],
    f: usize,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    tile: &[f32],
) {
    debug_assert_eq!(tile.len(), rows * cols);
    for r in 0..rows {
        buf[(row0 + r) * f + col0..(row0 + r) * f + col0 + cols]
            .copy_from_slice(&tile[r * cols..(r + 1) * cols]);
    }
}

/// The executor. Holds the compiled program, the partition-ordered
/// graph, the weights (packed once per executable), and the buffer
/// arena; `run` produces the final feature matrix.
///
/// With `dynamic` set, the executor consults the executable's density
/// threshold table (the GA02 section) per subshard and re-maps
/// dense-enough Sum/Mean aggregations from the SpDMM path onto the GEMM
/// path — a densified adjacency tile times the feature subfiber, the
/// exact weighted sum the edge stream computes — so results are
/// bit-equivalent up to float summation order.
pub struct FunctionalExecutor<'a, B: TileBackend> {
    pub exe: &'a Executable,
    pub graph: &'a PartitionedGraph,
    pub store: &'a WeightStore,
    pub backend: B,
    /// Density-aware dynamic kernel re-mapping on/off.
    pub dynamic: bool,
    /// Subshard tasks executed on a re-mapped kernel this run.
    pub remaps: u64,
    /// Tile/subshard tasks executed on the int8 datapath this run.
    pub quant_visits: u64,
    /// Quantize + dequantize epilogue passes this run.
    pub requant_ops: u64,
    /// int8 operand bytes streamed through quantized kernels this run.
    pub int8_bytes: u64,
    /// Reusable tile buffers; pass a warm arena via
    /// [`FunctionalExecutor::with_state`] for zero-alloc steady state.
    pub arena: BufferArena,
    packed: PackedWeightSet,
    /// int8 weight panels, built iff the program carries a scale table.
    packed_i8: Option<PackedWeightSetI8>,
}

impl<'a, B: TileBackend> FunctionalExecutor<'a, B> {
    pub fn new(
        exe: &'a Executable,
        graph: &'a PartitionedGraph,
        store: &'a WeightStore,
        backend: B,
    ) -> Self {
        Self::with_state(exe, graph, store, backend, BufferArena::new(), None, None)
    }

    /// Construct with a warm [`BufferArena`] and (optionally) the
    /// already-packed weight sets from an earlier run. Both packed sets
    /// are validated against the store's fingerprint and rebuilt on
    /// mismatch, so a stale cache can never be applied to different
    /// weights. The int8 set exists exactly when the program carries a
    /// GA03 scale table (the weights are quantized with the table's
    /// per-layer scales).
    pub fn with_state(
        exe: &'a Executable,
        graph: &'a PartitionedGraph,
        store: &'a WeightStore,
        backend: B,
        arena: BufferArena,
        packed: Option<PackedWeightSet>,
        packed_i8: Option<PackedWeightSetI8>,
    ) -> Self {
        assert_eq!(
            exe.cfg.n1, graph.cfg.n1,
            "graph partitioned with a different N1 than the executable"
        );
        let packed = match packed {
            Some(p) if p.fingerprint == store.fingerprint() => p,
            _ => PackedWeightSet::build(&exe.ir, store),
        };
        let packed_i8 = match (&exe.program.scales, packed_i8) {
            (Some(_), Some(p)) if p.fingerprint == store.fingerprint() => Some(p),
            (Some(st), _) => {
                let ws: Vec<(u16, f32)> =
                    st.entries.iter().map(|e| (e.layer_id, e.w_scale)).collect();
                Some(PackedWeightSetI8::build(&exe.ir, store, &ws))
            }
            (None, _) => None,
        };
        FunctionalExecutor {
            exe,
            graph,
            store,
            backend,
            dynamic: false,
            remaps: 0,
            quant_visits: 0,
            requant_ops: 0,
            int8_bytes: 0,
            arena,
            packed,
            packed_i8,
        }
    }

    /// Hand back the reusable state (arena + f32/int8 packed weights)
    /// so the next executor over the same executable skips packing and
    /// starts with a warm pool.
    pub fn into_state(self) -> (BufferArena, PackedWeightSet, Option<PackedWeightSetI8>) {
        (self.arena, self.packed, self.packed_i8)
    }

    /// Execute every Tiling Block in program order. Returns the last
    /// layer's output (n x f_out).
    pub fn run(&mut self, x: &[f32]) -> Vec<f32> {
        let exe = self.exe;
        let graph = self.graph;
        let store = self.store;
        let n = graph.n_vertices as usize;
        let n1 = exe.cfg.n1 as usize;
        let ir = &exe.ir;
        let f0 = ir.graph.feat_len as usize;
        assert_eq!(x.len(), n * f0);
        let mut outputs: HashMap<u16, Vec<f32>> = HashMap::new();
        let mut edge_w: Vec<f32> = self.arena.copy_f32(&graph.w);
        let mut last = 0u16;
        let scales = exe.program.scales.as_ref();
        for (layer, tasks) in ir.layers.iter().zip(&exe.tasks) {
            debug_assert_eq!(layer.id, tasks.layer_id);
            let qent = scales.and_then(|st| st.entry(layer.id)).copied();
            let f_in = layer.f_in as usize;
            let f_out = layer.f_out as usize;
            let h_in: &[f32] = match layer.parents.first() {
                Some(p) => outputs.get(p).expect("parent not computed").as_slice(),
                None => x,
            };
            let out: Vec<f32> = match layer.ltype {
                LayerType::Aggregate => {
                    // Re-map inputs are per layer: hoist the threshold
                    // table and this layer's provisional mode out of the
                    // per-subshard loop (mirrors sim::engine).
                    let remap_tt =
                        if self.dynamic { exe.program.thresholds.as_ref() } else { None };
                    let provisional = remap_tt
                        .and_then(|tt| tt.entry(layer.id))
                        .map(|e| e.provisional)
                        .unwrap_or(KernelMode::Spdmm);
                    let mut out = self.arena.take_f32(n * f_out);
                    for t in &tasks.tasks {
                        let TileTask::Aggregate {
                            fiber, shard, rows, cols, aggop, act, subshards,
                        } = t
                        else {
                            panic!("task/layer type mismatch")
                        };
                        let (rows, cols) = (*rows as usize, *cols as usize);
                        let (row0, col0) =
                            (*shard as usize * n1, *fiber as usize * exe.cfg.n2 as usize);
                        // Quantized Sum/Mean tile: the whole tile runs
                        // int8 with one i32 accumulator — integer
                        // addition is associative, so cross-subshard
                        // accumulation (and row-block threading) is
                        // exact and the single dequantize at the end
                        // fuses with the activation. Max/Min compare
                        // magnitudes and stay f32; the dynamic re-map
                        // is bypassed here because its densified GEMM
                        // re-orders the f32 summation, which would
                        // break the bit-identical guarantee the
                        // integer path provides.
                        if let Some(e) =
                            qent.filter(|_| matches!(aggop, AggOp::Sum | AggOp::Mean))
                        {
                            let mut acc_q = self.arena.take_i32(rows * cols);
                            let mut touched = self.arena.take_u32(rows);
                            for sref in subshards {
                                let k = sref.k as usize;
                                let csr = graph.csr(*shard as usize, k);
                                if csr.nnz() == 0 {
                                    continue;
                                }
                                debug_assert_eq!(csr.rows as usize, rows);
                                let range = graph.subshard(*shard as usize, k);
                                let ew = &edge_w[range];
                                let rows_k = (n - k * n1).min(n1);
                                let mut h_tile = self.arena.take_f32(rows_k * cols);
                                slice_tile_into(
                                    h_in, f_in, k * n1, rows_k, col0, cols, &mut h_tile,
                                );
                                let mut hq = self.arena.take_i8(rows_k * cols);
                                kernels::quantize_into(&h_tile, e.x_scale, &mut hq);
                                let mut ewq = self.arena.take_i8(ew.len());
                                kernels::quantize_into(ew, e.w_scale, &mut ewq);
                                kernels::spdmm_csr_i8_into(
                                    csr, &ewq, &hq, cols, &mut acc_q, &mut touched,
                                );
                                self.quant_visits += 1;
                                self.requant_ops += 2;
                                self.int8_bytes += (hq.len() + ewq.len()) as u64;
                                self.arena.recycle_f32(h_tile);
                                self.arena.recycle_i8(hq);
                                self.arena.recycle_i8(ewq);
                            }
                            // Untouched rows hold 0 in the integer
                            // accumulator — already the Sum neutral.
                            let mut acc = self.arena.take_f32(rows * cols);
                            let zb = self.arena.take_f32(cols);
                            kernels::dequant_bias_into(
                                &acc_q,
                                cols,
                                e.w_scale * e.x_scale,
                                &zb,
                                &mut acc,
                            );
                            self.requant_ops += 1;
                            ops::apply_act(&mut acc, *act);
                            write_tile(&mut out, f_out, row0, rows, col0, cols, &acc);
                            self.arena.recycle_i32(acc_q);
                            self.arena.recycle_u32(touched);
                            self.arena.recycle_f32(zb);
                            self.arena.recycle_f32(acc);
                            continue;
                        }
                        let neutral = match aggop {
                            AggOp::Sum | AggOp::Mean => 0.0f32,
                            AggOp::Max => f32::NEG_INFINITY,
                            AggOp::Min => f32::INFINITY,
                        };
                        let mut acc = self.arena.take_f32_filled(rows * cols, neutral);
                        let mut touched = self.arena.take_u32(rows);
                        for sref in subshards {
                            let k = sref.k as usize;
                            let csr = graph.csr(*shard as usize, k);
                            if csr.nnz() == 0 {
                                continue;
                            }
                            debug_assert_eq!(csr.rows as usize, rows);
                            let range = graph.subshard(*shard as usize, k);
                            let ew = &edge_w[range];
                            let rows_k = (n - k * n1).min(n1);
                            let mut h_tile = self.arena.take_f32(rows_k * cols);
                            slice_tile_into(h_in, f_in, k * n1, rows_k, col0, cols, &mut h_tile);
                            // Dynamic re-map: a dense-enough Sum/Mean
                            // subshard runs as a densified-adjacency GEMM
                            // (the same weighted sum, computed on the
                            // dense path the ACK would be re-mapped to).
                            // Max/Min are not a matmul — never re-mapped.
                            let dense_mode = matches!(aggop, AggOp::Sum | AggOp::Mean)
                                && remap_tt.is_some_and(|tt| {
                                    let d = tile_density(sref.ne, rows as u64, rows_k as u64);
                                    choose_mode(provisional, d, tt) == KernelMode::Gemm
                                });
                            if dense_mode {
                                self.remaps += 1;
                                let mut a = self.arena.take_f32(rows * rows_k);
                                for r in 0..rows {
                                    for slot in csr.row(r) {
                                        a[r * rows_k + csr.cols[slot] as usize] +=
                                            ew[csr.perm[slot] as usize];
                                    }
                                }
                                let zb = self.arena.take_f32(cols);
                                let mut part = self.arena.take_f32(rows * cols);
                                self.backend.gemm(&a, rows, rows_k, &h_tile, cols, &zb, &mut part);
                                // Sum-only re-map: in-place add is the
                                // cross-subshard combine (neutral is 0,
                                // so touched flags are not consulted).
                                for (o, &p) in acc.iter_mut().zip(&part) {
                                    *o += p;
                                }
                                self.arena.recycle_f32(a);
                                self.arena.recycle_f32(zb);
                                self.arena.recycle_f32(part);
                            } else {
                                self.backend.spdmm_csr(
                                    csr, ew, &h_tile, cols, *aggop, &mut acc, &mut touched,
                                );
                            }
                            self.arena.recycle_f32(h_tile);
                        }
                        // Untouched rows -> 0 (kernel convention); for
                        // Sum/Mean the neutral already is 0.
                        if neutral != 0.0 {
                            for (r, &t) in touched.iter().enumerate() {
                                if t == 0 {
                                    acc[r * cols..(r + 1) * cols].fill(0.0);
                                }
                            }
                        }
                        ops::apply_act(&mut acc, *act);
                        write_tile(&mut out, f_out, row0, rows, col0, cols, &acc);
                        self.arena.recycle_f32(acc);
                        self.arena.recycle_u32(touched);
                    }
                    out
                }
                LayerType::Linear => {
                    let (_, b) = store.get(layer.id);
                    let mut out = self.arena.take_f32(n * f_out);
                    for t in &tasks.tasks {
                        let TileTask::Linear { row0, rows, act, .. } = t else {
                            panic!("task/layer type mismatch")
                        };
                        let rows = *rows as usize;
                        let row0 = *row0 as usize;
                        // Full-width row blocks are contiguous in both
                        // h_in and out: no tile copies on this path.
                        let h_tile = &h_in[row0 * f_in..(row0 + rows) * f_in];
                        let o = &mut out[row0 * f_out..(row0 + rows) * f_out];
                        match (qent, self.packed_i8.as_ref()) {
                            (Some(e), Some(pi8)) => {
                                // int8 row block: quantize features at
                                // the calibrated scale, multiply into
                                // i32, dequantize + bias fused ahead of
                                // the activation.
                                let pw8 = pi8.get(layer.id);
                                let mut hq = self.arena.take_i8(rows * f_in);
                                kernels::quantize_into(h_tile, e.x_scale, &mut hq);
                                let mut acc = self.arena.take_i32(rows * f_out);
                                kernels::gemm_i8_packed_into(&hq, rows, pw8, &mut acc);
                                kernels::dequant_bias_into(
                                    &acc,
                                    f_out,
                                    e.w_scale * e.x_scale,
                                    b,
                                    o,
                                );
                                self.quant_visits += 1;
                                self.requant_ops += 2;
                                self.int8_bytes += (hq.len() + pw8.k * pw8.n) as u64;
                                self.arena.recycle_i8(hq);
                                self.arena.recycle_i32(acc);
                            }
                            _ => {
                                let pw = self.packed.get(layer.id);
                                self.backend.gemm_packed(h_tile, rows, pw, b, o);
                            }
                        }
                        ops::apply_act(o, *act);
                    }
                    out
                }
                LayerType::VectorInner => {
                    for t in &tasks.tasks {
                        let TileTask::VectorInner { i, j, act, .. } = t else {
                            panic!("task/layer type mismatch")
                        };
                        // The *graph* decides which tiles hold edges: a
                        // shape-bucketed executable carries canonical
                        // (not member) edge counts, so the task's `ne`
                        // is timing metadata only.
                        let csr = graph.csr(*i as usize, *j as usize);
                        if csr.nnz() == 0 {
                            continue;
                        }
                        let range = graph.subshard(*i as usize, *j as usize);
                        debug_assert_eq!(range.len(), csr.nnz());
                        let rows_j = (n - *j as usize * n1).min(n1);
                        let rows_i = (n - *i as usize * n1).min(n1);
                        // Full-width row blocks: contiguous, no copies.
                        let hl = &h_in[*j as usize * n1 * f_in..][..rows_j * f_in];
                        let hr = &h_in[*i as usize * n1 * f_in..][..rows_i * f_in];
                        let mut vals = self.arena.take_f32(range.len());
                        self.backend.sddmm_csr(csr, hl, hr, f_in, &mut vals);
                        ops::apply_act(&mut vals, *act);
                        // Scatter CSR slot order back to edge order.
                        let ew_out = &mut edge_w[range];
                        for (slot, &v) in vals.iter().enumerate() {
                            ew_out[csr.perm[slot] as usize] = v;
                        }
                        self.arena.recycle_f32(vals);
                    }
                    // Features pass through a Vector-Inner layer.
                    self.arena.copy_f32(h_in)
                }
                LayerType::VectorAdd => {
                    let h2: &[f32] = match layer.parents.get(1) {
                        Some(p) => outputs.get(p).expect("parent not computed").as_slice(),
                        None => x,
                    };
                    let mut out = self.arena.take_f32(n * f_out);
                    for t in &tasks.tasks {
                        let TileTask::VectorAdd { fiber, shard, rows, cols, act } = t
                        else {
                            panic!("task/layer type mismatch")
                        };
                        let (rows, cols) = (*rows as usize, *cols as usize);
                        let (row0, col0) =
                            (*shard as usize * n1, *fiber as usize * exe.cfg.n2 as usize);
                        let mut ta = self.arena.take_f32(rows * cols);
                        let mut tb = self.arena.take_f32(rows * cols);
                        slice_tile_into(h_in, f_in, row0, rows, col0, cols, &mut ta);
                        slice_tile_into(h2, f_in, row0, rows, col0, cols, &mut tb);
                        let mut o = self.arena.take_f32(rows * cols);
                        self.backend.vecadd(&ta, &tb, &mut o);
                        ops::apply_act(&mut o, *act);
                        write_tile(&mut out, f_out, row0, rows, col0, cols, &o);
                        self.arena.recycle_f32(ta);
                        self.arena.recycle_f32(tb);
                        self.arena.recycle_f32(o);
                    }
                    out
                }
                LayerType::Activation | LayerType::BatchNorm => {
                    // Edge-score activation (parent is a Vector-Inner):
                    // acts on the edge-weight state, features pass through
                    // (mirrors golden_forward's semantics).
                    let edge_parent = layer
                        .parents
                        .first()
                        .map(|&p| {
                            ir.layers.iter().any(|q| {
                                q.id == p && q.ltype == LayerType::VectorInner
                            })
                        })
                        .unwrap_or(false);
                    if edge_parent && layer.ltype == LayerType::Activation {
                        ops::apply_act(&mut edge_w, layer.act);
                        let pass = self.arena.copy_f32(h_in);
                        outputs.insert(layer.id, pass);
                        last = layer.id;
                        continue;
                    }
                    let mut out = self.arena.take_f32(n * f_out);
                    for t in &tasks.tasks {
                        let TileTask::Eltwise { fiber, shard, rows, cols, act, batchnorm } =
                            t
                        else {
                            panic!("task/layer type mismatch")
                        };
                        let (rows, cols) = (*rows as usize, *cols as usize);
                        let (row0, col0) =
                            (*shard as usize * n1, *fiber as usize * exe.cfg.n2 as usize);
                        let mut tile = self.arena.take_f32(rows * cols);
                        slice_tile_into(h_in, f_in, row0, rows, col0, cols, &mut tile);
                        if !batchnorm {
                            ops::apply_act(&mut tile, *act);
                        } // inference BN with unit scale: identity
                        write_tile(&mut out, f_out, row0, rows, col0, cols, &tile);
                        self.arena.recycle_f32(tile);
                    }
                    out
                }
            };
            outputs.insert(layer.id, out);
            last = layer.id;
        }
        let result = outputs.remove(&last).unwrap();
        for (_, buf) in outputs.drain() {
            self.arena.recycle_f32(buf);
        }
        self.arena.recycle_f32(edge_w);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::config::HwConfig;
    use crate::exec::golden::golden_forward;
    use crate::graph::{rmat::rmat_edges, CooGraph, GraphMeta, PartitionConfig};
    use crate::ir::ZooModel;

    fn setup(
        model: ZooModel,
        n: u64,
        e: u64,
        f: u64,
    ) -> (Executable, PartitionedGraph, CooGraph, WeightStore) {
        let meta = GraphMeta::new("t", n, e, f, 4);
        let g = rmat_edges(meta, Default::default(), 9).gcn_normalized();
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        let tiles = pg.tile_counts();
        let ir = model.build(g.meta.clone());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        (exe, pg, g, store)
    }

    fn max_err(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn functional_matches_golden_multi_shard() {
        // 300 vertices at N1=128 -> 3 shards; exercises cross-subshard
        // accumulation and fiber splitting.
        for model in [ZooModel::B1, ZooModel::B7] {
            let (exe, pg, g, store) = setup(model, 300, 1500, 32);
            let x = g.random_features(5);
            let golden = golden_forward(&exe.ir, &g, &store, &x);
            let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
            let got = fx.run(&x);
            let err = max_err(&golden, &got);
            assert!(err < 1e-3, "{}: max err {err}", exe.ir.name);
        }
    }

    #[test]
    fn functional_matches_golden_all_models() {
        for model in crate::ir::ALL_MODELS {
            let (exe, pg, g, store) = setup(model, 200, 800, 16);
            let x = g.random_features(6);
            let golden = golden_forward(&exe.ir, &g, &store, &x);
            let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
            let got = fx.run(&x);
            let err = max_err(&golden, &got);
            // b6/b8 exponentials amplify error; scale tolerance by output
            // magnitude.
            let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
            assert!(
                err <= 1e-3 * scale.max(1.0),
                "{}: max err {err} (scale {scale})",
                exe.ir.name
            );
        }
    }

    #[test]
    fn reference_backend_matches_optimized_backend() {
        // The naive baseline and the optimized backend must agree on the
        // same compiled schedule (the bench's apples-to-apples premise).
        for model in [ZooModel::B1, ZooModel::B6] {
            let (exe, pg, g, store) = setup(model, 260, 1200, 32);
            let x = g.random_features(8);
            let a = FunctionalExecutor::new(&exe, &pg, &store, ReferenceBackend).run(&x);
            let b = FunctionalExecutor::new(&exe, &pg, &store, RustBackend).run(&x);
            let scale = a.iter().fold(1f32, |m, v| m.max(v.abs()));
            let err = max_err(&a, &b);
            assert!(
                err <= 1e-3 * scale.max(1.0),
                "{}: backend divergence {err}",
                exe.ir.name
            );
        }
    }

    #[test]
    fn tile_slicing_roundtrip() {
        let n = 7;
        let f = 5;
        let buf: Vec<f32> = (0..n * f).map(|i| i as f32).collect();
        let tile = slice_tile(&buf, f, 2, 3, 1, 2);
        assert_eq!(tile.len(), 6);
        assert_eq!(tile[0], (2 * f + 1) as f32);
        let mut tile2 = vec![0f32; 6];
        slice_tile_into(&buf, f, 2, 3, 1, 2, &mut tile2);
        assert_eq!(tile, tile2);
        let mut buf2 = vec![0f32; n * f];
        write_tile(&mut buf2, f, 2, 3, 1, 2, &tile);
        assert_eq!(buf2[2 * f + 1], tile[0]);
        assert_eq!(buf2[4 * f + 2], tile[5]);
    }

    #[test]
    fn max_aggregation_cross_shard() {
        // GraphGym point with Max aggregation over a multi-shard graph:
        // the touched-row logic must match the golden result.
        use crate::ir::GraphGymConfig;
        let meta = GraphMeta::new("t", 300, 2000, 16, 4);
        let g = rmat_edges(meta, Default::default(), 13);
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        let ggcfg = GraphGymConfig {
            aggop: crate::isa::AggOp::Max,
            n_mp: 2,
            hidden: 16,
            ..Default::default()
        };
        let ir = ggcfg.build("gg-max", g.meta.clone());
        let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 44);
        let x = g.random_features(7);
        let golden = golden_forward(&exe.ir, &g, &store, &x);
        let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let got = fx.run(&x);
        let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
        let err = max_err(&golden, &got);
        assert!(err <= 1e-3 * scale.max(1.0), "max-agg err {err}");
    }

    #[test]
    fn warm_arena_serves_repeat_runs_without_fresh_allocations() {
        // The zero-alloc steady-state guarantee: after one warm run,
        // every buffer the hot loop needs comes from the pool. The one
        // allowed fresh allocation per run replaces the output matrix
        // that escaped to the caller.
        let (exe, pg, g, store) = setup(ZooModel::B1, 300, 1500, 32);
        let x = g.random_features(5);
        let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let first = fx.run(&x);
        let (arena, packed, _) = fx.into_state();
        let cold_fresh = arena.stats().fresh;
        let mut fx2 = FunctionalExecutor::with_state(
            &exe,
            &pg,
            &store,
            RustBackend,
            arena,
            Some(packed),
            None,
        );
        let second = fx2.run(&x);
        assert_eq!(first, second, "warm run changed numerics");
        let warm_fresh = fx2.arena.stats().fresh - cold_fresh;
        assert!(warm_fresh <= 1, "warm run allocated {warm_fresh} fresh buffers");
    }

    #[test]
    fn quantized_run_matches_golden_within_calibrated_bound() {
        use crate::quant::{calibrate, CalibrationProfile};
        for model in [ZooModel::B1, ZooModel::B7] {
            let (mut exe, pg, g, store) = setup(model, 300, 1500, 32);
            let x = g.random_features(5);
            let golden = golden_forward(&exe.ir, &g, &store, &x);
            let cal = calibrate(&exe.ir, &store, &CalibrationProfile::exact(&g, &x));
            assert!(cal.bound.is_finite() && cal.bound > 0.0);
            exe.program.scales = Some(cal.table);
            let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
            let got = fx.run(&x);
            assert!(
                fx.quant_visits > 0 && fx.requant_ops > 0 && fx.int8_bytes > 0,
                "{}: int8 datapath never engaged",
                exe.ir.name
            );
            let err = max_err(&golden, &got);
            assert!(
                err <= cal.bound,
                "{}: int8 err {err} exceeds calibrated bound {}",
                exe.ir.name,
                cal.bound
            );
            // Integer accumulation is order-independent: a repeat run
            // is bit-identical, not merely close.
            let again = FunctionalExecutor::new(&exe, &pg, &store, RustBackend).run(&x);
            assert_eq!(got, again, "{}: quantized run not reproducible", exe.ir.name);
        }
    }

    #[test]
    fn warm_quantized_runs_stay_zero_alloc() {
        use crate::quant::{calibrate, CalibrationProfile};
        let (mut exe, pg, g, store) = setup(ZooModel::B1, 300, 1500, 32);
        let x = g.random_features(5);
        let cal = calibrate(&exe.ir, &store, &CalibrationProfile::exact(&g, &x));
        exe.program.scales = Some(cal.table);
        let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let first = fx.run(&x);
        let (arena, packed, packed_i8) = fx.into_state();
        assert!(packed_i8.is_some(), "scaled program must build int8 panels");
        let cold_fresh = arena.stats().fresh;
        let mut fx2 = FunctionalExecutor::with_state(
            &exe,
            &pg,
            &store,
            RustBackend,
            arena,
            Some(packed),
            packed_i8,
        );
        let second = fx2.run(&x);
        assert_eq!(first, second, "warm quantized run changed numerics");
        // The f32 zero-alloc invariant extends to the int8 pools: a
        // warm quantized run draws every i8/i32 buffer from the arena.
        let warm_fresh = fx2.arena.stats().fresh - cold_fresh;
        assert!(warm_fresh <= 1, "warm quantized run allocated {warm_fresh} fresh buffers");
        let s = fx2.arena.stats();
        assert!(s.by_i8.reused > 0 && s.by_i32.reused > 0, "int8 pools never reused");
    }
}
