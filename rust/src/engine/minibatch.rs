//! Functional mini-batch execution through the shape-bucketed
//! executable cache.
//!
//! [`MiniBatchRunner`] is the numerics-producing counterpart of the
//! serving fleet's bucket path: it compiles one canonical program per
//! `(model, `[`BucketShape`]`)`, keeps per-bucket warm state (a
//! [`BufferArena`] and the packed weight panels), and runs any member
//! ego-net by re-homing it in the bucket's padded vertex space. Padding
//! rows are zero and edge-free, so live-row outputs are bit-identical
//! to an exact-shape execution (pinned in `rust/tests/minibatch.rs`).
//!
//! The runner is what the golden-equivalence chain tests against: full
//! neighborhood sampling to the model's Aggregate depth must reproduce
//! the whole-graph golden outputs on target rows for every zoo model.

use crate::compiler::bucket::{compile_bucket, BucketShape};
use crate::compiler::Executable;
use crate::config::HwConfig;
use crate::exec::{
    BufferArena, FunctionalExecutor, PackedWeightSet, PackedWeightSetI8, RustBackend, WeightStore,
};
use crate::graph::sample::EgoNet;
use crate::graph::PartitionedGraph;
use crate::ir::ZooModel;
use std::collections::HashMap;

/// Per-run result of a mini-batch execution.
#[derive(Clone, Debug)]
pub struct MiniBatchProfile {
    /// The bucket the ego-net executed in.
    pub shape: BucketShape,
    /// Whether the bucket program was already compiled in this runner.
    pub bucket_hit: bool,
    /// Output rows of the target vertices (`n_targets x n_classes`,
    /// row-major, in the ego-net's local target order).
    pub targets_out: Vec<f32>,
    pub sampled_vertices: u64,
    pub sampled_edges: u64,
    /// Rows the bucket padded the ego-net to.
    pub padded_vertices: u64,
}

/// One bucket's compiled program plus its warm execution state.
struct BucketEntry {
    exe: Executable,
    store: WeightStore,
    arena: BufferArena,
    packed: Option<PackedWeightSet>,
    packed_i8: Option<PackedWeightSetI8>,
}

/// Bucket-cached functional executor for ego-networks.
pub struct MiniBatchRunner {
    hw: HwConfig,
    weight_seed: u64,
    entries: HashMap<(ZooModel, BucketShape), BucketEntry>,
    pub bucket_hits: u64,
    pub bucket_misses: u64,
}

impl MiniBatchRunner {
    /// `weight_seed` feeds [`WeightStore::deterministic`] per bucket
    /// program; because layer ids and dimensions are independent of
    /// graph size, the same seed yields the same weights as the
    /// whole-graph model — which is what makes golden cross-checks
    /// possible.
    pub fn new(hw: HwConfig, weight_seed: u64) -> MiniBatchRunner {
        MiniBatchRunner {
            hw,
            weight_seed,
            entries: HashMap::new(),
            bucket_hits: 0,
            bucket_misses: 0,
        }
    }

    /// Distinct bucket programs compiled so far.
    pub fn buckets(&self) -> usize {
        self.entries.len()
    }

    /// Execute `ego` under `model` in its covering bucket. `x_full` is
    /// the *parent graph's* feature matrix; the runner gathers and
    /// zero-pads the sampled rows itself.
    pub fn run(&mut self, model: ZooModel, ego: &EgoNet, x_full: &[f32]) -> MiniBatchProfile {
        let shape = BucketShape::for_graph(&ego.graph.meta);
        self.run_shaped(model, shape, ego, x_full)
    }

    /// [`MiniBatchRunner::run`] with an explicit shape. The
    /// padding-equivalence test passes [`BucketShape::exact`] here to
    /// compare unpadded against bucket-padded execution.
    pub fn run_shaped(
        &mut self,
        model: ZooModel,
        shape: BucketShape,
        ego: &EgoNet,
        x_full: &[f32],
    ) -> MiniBatchProfile {
        assert_eq!(shape.f as u64, ego.graph.meta.feat_len, "bucket/ego feature length");
        assert_eq!(shape.c as u64, ego.graph.meta.n_classes, "bucket/ego class count");
        assert!((shape.v as usize) >= ego.n(), "bucket smaller than the ego-net");
        let key = (model, shape);
        let hit = self.entries.contains_key(&key);
        if hit {
            self.bucket_hits += 1;
        } else {
            self.bucket_misses += 1;
        }
        let hw = self.hw.clone();
        let seed = self.weight_seed;
        let entry = self.entries.entry(key).or_insert_with(|| {
            let exe = compile_bucket(model, shape, &hw);
            let store = WeightStore::deterministic(&exe.ir, seed);
            BucketEntry { exe, store, arena: BufferArena::new(), packed: None, packed_i8: None }
        });
        let f = ego.graph.meta.feat_len as usize;
        let padded = ego.padded_graph(shape.v as u64);
        let pg = PartitionedGraph::build(&padded, entry.exe.cfg);
        let x = ego.padded_features(x_full, f, shape.v as usize);
        let arena = std::mem::take(&mut entry.arena);
        let packed = entry.packed.take();
        let packed_i8 = entry.packed_i8.take();
        let mut fx = FunctionalExecutor::with_state(
            &entry.exe,
            &pg,
            &entry.store,
            RustBackend,
            arena,
            packed,
            packed_i8,
        );
        let out = fx.run(&x);
        let (arena, packed, packed_i8) = fx.into_state();
        entry.arena = arena;
        entry.packed = Some(packed);
        entry.packed_i8 = packed_i8;
        let c = ego.graph.meta.n_classes as usize;
        MiniBatchProfile {
            shape,
            bucket_hit: hit,
            targets_out: out[..ego.n_targets * c].to_vec(),
            sampled_vertices: ego.n() as u64,
            sampled_edges: ego.m() as u64,
            padded_vertices: shape.v as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::rmat_edges;
    use crate::graph::{GraphMeta, Sampler};

    #[test]
    fn bucket_cache_hits_on_nearby_egonets() {
        let meta = GraphMeta::new("t", 400, 2400, 16, 4);
        let g = rmat_edges(meta, Default::default(), 21).gcn_normalized();
        let x = g.random_features(3);
        let sampler = Sampler::new(g);
        let mut runner = MiniBatchRunner::new(HwConfig::functional_tiles(), 33);
        let a = sampler.sample(&[1, 2], &[4, 4], 5);
        let b = sampler.sample(&[7, 9], &[4, 4], 6);
        let pa = runner.run(ZooModel::B1, &a, &x);
        let pb = runner.run(ZooModel::B1, &b, &x);
        assert!(!pa.bucket_hit);
        // Different targets, same size class: one compiled program.
        if pa.shape == pb.shape {
            assert!(pb.bucket_hit);
            assert_eq!(runner.buckets(), 1);
        }
        assert_eq!((runner.bucket_hits + runner.bucket_misses) as usize, 2);
        assert_eq!(pa.targets_out.len(), a.n_targets * 4);
        assert!(pa.targets_out.iter().all(|v| v.is_finite()));
    }
}
