//! The engine layer: one trait, three execution substrates.
//!
//! The compiler's [`Executable`] is the single handoff artifact of the
//! whole system — the same compiled program runs on
//!
//! * [`GoldenEngine`] — the whole-graph rust reference (ground truth),
//! * [`FunctionalEngine`] — the partition-centric tile executor over the
//!   pure-rust ops (and, behind the `pjrt` feature, `PjrtEngine` over
//!   the AOT-compiled Pallas/JAX kernels),
//! * [`SimEngine`] — the cycle-level overlay model (T_LoH).
//!
//! ```text
//!                 ModelIr ──compile──▶ Executable
//!                                         │
//!              ┌──────────────┬───────────┼──────────────┐
//!              ▼              ▼           ▼              ▼
//!        GoldenEngine  FunctionalEngine  PjrtEngine  SimEngine
//!        (whole-graph)  (rust tiles)    (HLO tiles)  (cycle model)
//!              └──────────────┴───────────┴──────────────┘
//!                              ▼
//!                         ExecProfile
//!          (latency, cycles, launches, bytes, re-maps, output)
//! ```
//!
//! Every engine returns the same [`ExecProfile`] shape, so callers — the
//! serving fleet, the harness, equivalence tests — compose against the
//! trait instead of hardwiring one substrate. Functional engines need
//! graph data ([`EngineInput`]); timing-only engines (the simulator)
//! accept `None` and never materialize features, which is what lets the
//! serving coordinator run Reddit-scale programs it could never hold in
//! memory.

pub mod minibatch;
pub mod streaming;

use crate::compiler::Executable;
use crate::config::HwConfig;
use crate::exec::{
    golden_forward, BufferArena, CountingBackend, FunctionalExecutor, PackedWeightSet,
    PackedWeightSetI8, RustBackend, WeightStore,
};
use crate::graph::{CooGraph, PartitionedGraph};
use crate::sim::{simulate, simulate_dynamic};
use crate::util::timed;
use anyhow::{bail, Result};

pub use minibatch::{MiniBatchProfile, MiniBatchRunner};
pub use streaming::StreamingSession;

/// The functional payload: graph + weights + input features. Timing-only
/// engines ignore it (and accept `None`).
pub struct EngineInput<'a> {
    pub graph: &'a CooGraph,
    pub partitioned: &'a PartitionedGraph,
    pub store: &'a WeightStore,
    /// Input features, row-major (n_vertices x feat_len).
    pub x: &'a [f32],
}

/// Unified per-run profile every engine reports.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecProfile {
    pub engine: &'static str,
    /// Seconds. Wall-clock for functional engines (varies run to run),
    /// virtual (cycles / frequency) for the simulator — check
    /// [`InferenceEngine::deterministic`] before replay-comparing.
    pub latency_s: f64,
    /// Modeled hardware cycles (0 for functional engines).
    pub cycles: u64,
    /// Kernel launches (functional) or Tiling-Block dispatches (sim).
    pub kernel_launches: u64,
    /// Bytes streamed through kernels (functional) or DDR (sim).
    pub bytes_moved: u64,
    /// Density-driven kernel re-maps this run (see [`crate::sparsity`]):
    /// subshard tasks run on the dense path (functional) or compute
    /// instructions charged at a cheaper mode (sim). 0 when dynamic
    /// re-mapping is off or the engine has no dynamic path.
    pub remaps: u64,
    /// Tile tasks (functional) or Tiling Blocks (sim) executed on the
    /// int8 datapath. 0 unless the program carries a GA03 scale table.
    pub quant_visits: u64,
    /// Quantize/dequantize epilogue passes (functional) or re-quantized
    /// compute instructions (sim).
    pub requant_ops: u64,
    /// int8 operand bytes streamed through quantized kernels
    /// (functional) or modeled 1-byte DDR operand traffic (sim).
    pub int8_bytes: u64,
    /// Final feature matrix, when the engine computes real numerics.
    pub output: Option<Vec<f32>>,
}

/// An execution substrate for compiled programs.
pub trait InferenceEngine {
    /// Short stable identifier of the substrate (`"golden"`,
    /// `"functional"`, `"pjrt"`, `"sim"`), echoed in
    /// [`ExecProfile::engine`] so profiles stay attributable after
    /// engines are boxed behind the trait.
    fn name(&self) -> &'static str;

    /// True when repeated runs of the same executable produce
    /// bit-identical profiles (virtual time, no wall-clock). The serving
    /// fleet replays only on deterministic engines; wall-clock engines
    /// (golden, functional, pjrt) report measured latency that varies
    /// run to run.
    fn deterministic(&self) -> bool {
        false
    }

    /// One-time preparation for repeated runs of `exe`: engines with
    /// per-executable state build it here — the functional engine packs
    /// every Linear layer's weights into the blocked-GEMM panel layout
    /// and warms its buffer arena. `run` must work without a prior
    /// `prepare` (it prepares lazily); calling it just moves the packing
    /// cost off the first request's critical path. The default is a
    /// no-op for stateless engines.
    fn prepare(&mut self, _exe: &Executable, _data: Option<&EngineInput<'_>>) -> Result<()> {
        Ok(())
    }

    /// Enable or disable density-aware dynamic kernel re-mapping
    /// ([`crate::sparsity`]): when on, the engine consults the
    /// executable's threshold table (the `.ga` GA02 section) per Tiling
    /// Block and overrides the provisional GEMM/SpDMM choice where the
    /// measured density crosses it. Engines without a dynamic path
    /// (golden; pjrt) ignore the call — the default is a no-op —
    /// because they either never consult the kernel mapping or execute
    /// fixed AOT-compiled kernels.
    fn set_dynamic_remap(&mut self, _enabled: bool) {}

    /// Run `exe`, returning the unified profile. `data` carries the
    /// functional payload (graph, partitioning, weights, input
    /// features); engines that only model time accept `None` and never
    /// materialize features, so Reddit-scale programs still profile.
    fn run(&mut self, exe: &Executable, data: Option<&EngineInput<'_>>) -> Result<ExecProfile>;
}

/// Tile-schedule engines require the graph to be partitioned with the
/// exact (N1, N2) the executable was compiled for — a mismatch would
/// misindex tiles silently.
fn check_partition(exe: &Executable, d: &EngineInput<'_>) -> Result<()> {
    if exe.cfg != d.partitioned.cfg {
        bail!(
            "graph partitioned with (N1={}, N2={}) but executable wants (N1={}, N2={})",
            d.partitioned.cfg.n1,
            d.partitioned.cfg.n2,
            exe.cfg.n1,
            exe.cfg.n2
        );
    }
    Ok(())
}

/// Whole-graph rust reference executor (ground truth).
#[derive(Clone, Copy, Debug, Default)]
pub struct GoldenEngine;

impl InferenceEngine for GoldenEngine {
    fn name(&self) -> &'static str {
        "golden"
    }

    fn run(&mut self, exe: &Executable, data: Option<&EngineInput<'_>>) -> Result<ExecProfile> {
        let Some(d) = data else {
            bail!("golden engine needs graph data (EngineInput)");
        };
        let (out, secs) = timed(|| golden_forward(&exe.ir, d.graph, d.store, d.x));
        // Whole-matrix traffic: features in/out, weights, edge list.
        let bytes = 4 * (d.x.len() + out.len()) as u64
            + d.store.total_bytes()
            + 12 * d.graph.m() as u64;
        Ok(ExecProfile {
            engine: "golden",
            latency_s: secs,
            cycles: 0,
            kernel_launches: exe.ir.layers.len() as u64,
            bytes_moved: bytes,
            remaps: 0,
            quant_visits: 0,
            requant_ops: 0,
            int8_bytes: 0,
            output: Some(out),
        })
    }
}

/// Compiled-schedule executor over the optimized pure-rust tile
/// kernels: proves the ISA -> schedule -> kernels composition
/// functionally. With `dynamic` set (or via
/// [`InferenceEngine::set_dynamic_remap`]), dense-enough aggregation
/// subshards run on the densified GEMM path instead of the SpDMM edge
/// stream — same numerics, re-mapped kernel.
///
/// The engine is stateful across runs: it keeps a [`BufferArena`] (so
/// steady-state inference reuses every tile buffer instead of
/// allocating) and the [`PackedWeightSet`] of the last-prepared
/// executable (weights are packed into the blocked-GEMM panel layout
/// once, not per run — the cache is fingerprint-checked against the
/// store, so different weights always repack).
#[derive(Debug, Default)]
pub struct FunctionalEngine {
    /// Density-aware dynamic kernel re-mapping on/off.
    pub dynamic: bool,
    arena: BufferArena,
    packed: Option<PackedWeightSet>,
    /// int8 weight panels, kept warm when serving scaled programs.
    packed_i8: Option<PackedWeightSetI8>,
}

impl FunctionalEngine {
    /// True when a packed weight set from `prepare` (or an earlier run)
    /// is resident.
    pub fn prepared(&self) -> bool {
        self.packed.is_some()
    }

    /// Arena counters (fresh/reused/recycled buffers across all runs).
    pub fn arena_stats(&self) -> crate::exec::ArenaStats {
        self.arena.stats()
    }
}

impl InferenceEngine for FunctionalEngine {
    fn name(&self) -> &'static str {
        "functional"
    }

    fn set_dynamic_remap(&mut self, enabled: bool) {
        self.dynamic = enabled;
    }

    fn prepare(&mut self, exe: &Executable, data: Option<&EngineInput<'_>>) -> Result<()> {
        let Some(d) = data else {
            bail!("functional engine needs graph data (EngineInput) to prepare");
        };
        check_partition(exe, d)?;
        self.packed = Some(PackedWeightSet::build(&exe.ir, d.store));
        Ok(())
    }

    fn run(&mut self, exe: &Executable, data: Option<&EngineInput<'_>>) -> Result<ExecProfile> {
        let Some(d) = data else {
            bail!("functional engine needs graph data (EngineInput)");
        };
        check_partition(exe, d)?;
        let arena = std::mem::take(&mut self.arena);
        let mut fx = FunctionalExecutor::with_state(
            exe,
            d.partitioned,
            d.store,
            CountingBackend::new(RustBackend),
            arena,
            self.packed.take(),
            self.packed_i8.take(),
        );
        fx.dynamic = self.dynamic;
        let (out, secs) = timed(|| fx.run(d.x));
        let profile = ExecProfile {
            engine: "functional",
            latency_s: secs,
            cycles: 0,
            // Quantized tiles bypass the TileBackend (the int8 kernels
            // are invoked directly), so their dispatches and operand
            // bytes are added back from the executor's counters.
            kernel_launches: fx.backend.launches + fx.quant_visits,
            bytes_moved: fx.backend.bytes + fx.int8_bytes,
            remaps: fx.remaps,
            quant_visits: fx.quant_visits,
            requant_ops: fx.requant_ops,
            int8_bytes: fx.int8_bytes,
            output: Some(out),
        };
        let (arena, packed, packed_i8) = fx.into_state();
        self.arena = arena;
        self.packed = Some(packed);
        self.packed_i8 = packed_i8;
        Ok(profile)
    }
}

/// Cycle-level overlay model: virtual time from the compiled binary,
/// never touches feature values (runs at any graph scale). With
/// `dynamic` set, the model charges each compute instruction at the
/// cheaper of its encoded mode and the density-selected re-map
/// ([`crate::sim::simulate_dynamic`]).
#[derive(Clone, Debug)]
pub struct SimEngine {
    pub hw: HwConfig,
    /// Density-aware dynamic kernel re-mapping on/off.
    pub dynamic: bool,
}

impl SimEngine {
    pub fn new(hw: HwConfig) -> SimEngine {
        SimEngine { hw, dynamic: false }
    }
}

impl InferenceEngine for SimEngine {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn deterministic(&self) -> bool {
        true
    }

    fn set_dynamic_remap(&mut self, enabled: bool) {
        self.dynamic = enabled;
    }

    fn run(&mut self, exe: &Executable, _data: Option<&EngineInput<'_>>) -> Result<ExecProfile> {
        let sim = if self.dynamic {
            simulate_dynamic(&exe.program, &self.hw)
        } else {
            simulate(&exe.program, &self.hw)
        };
        Ok(ExecProfile {
            engine: "sim",
            latency_s: sim.loh_seconds(),
            cycles: sim.cycles,
            kernel_launches: sim.layers.iter().map(|l| l.n_blocks as u64).sum(),
            bytes_moved: sim.total_mem_bytes,
            remaps: sim.remaps,
            quant_visits: sim.quant_blocks,
            requant_ops: sim.requant_ops,
            int8_bytes: sim.int8_bytes,
            output: None,
        })
    }
}

/// Compiled-schedule executor over the AOT-compiled Pallas/JAX HLO
/// kernels on the PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine<'rt> {
    rt: &'rt crate::runtime::PjrtRuntime,
}

#[cfg(feature = "pjrt")]
impl<'rt> PjrtEngine<'rt> {
    pub fn new(rt: &'rt crate::runtime::PjrtRuntime) -> PjrtEngine<'rt> {
        PjrtEngine { rt }
    }
}

#[cfg(feature = "pjrt")]
impl<'rt> InferenceEngine for PjrtEngine<'rt> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run(&mut self, exe: &Executable, data: Option<&EngineInput<'_>>) -> Result<ExecProfile> {
        let Some(d) = data else {
            bail!("pjrt engine needs graph data (EngineInput)");
        };
        check_partition(exe, d)?;
        let backend = CountingBackend::new(crate::runtime::PjrtBackend::new(self.rt)?);
        let mut fx = FunctionalExecutor::new(exe, d.partitioned, d.store, backend);
        let (out, secs) = timed(|| fx.run(d.x));
        Ok(ExecProfile {
            engine: "pjrt",
            latency_s: secs,
            cycles: 0,
            kernel_launches: fx.backend.launches + fx.quant_visits,
            bytes_moved: fx.backend.bytes + fx.int8_bytes,
            remaps: 0,
            quant_visits: fx.quant_visits,
            requant_ops: fx.requant_ops,
            int8_bytes: fx.int8_bytes,
            output: Some(out),
        })
    }
}

/// Every engine constructible without an external runtime, in reference
/// order (golden first).
pub fn default_engines(hw: &HwConfig) -> Vec<Box<dyn InferenceEngine>> {
    vec![
        Box::new(GoldenEngine),
        Box::new(FunctionalEngine::default()),
        Box::new(SimEngine::new(hw.clone())),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
    use crate::ir::ZooModel;

    fn setup(model: ZooModel) -> (Executable, CooGraph, PartitionedGraph, WeightStore, Vec<f32>) {
        let meta = GraphMeta::new("t", 300, 1500, 32, 4);
        let g = rmat_edges(meta, Default::default(), 9).gcn_normalized();
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        let ir = model.build(g.meta.clone());
        let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        let x = g.random_features(5);
        (exe, g, pg, store, x)
    }

    #[test]
    fn golden_and_functional_agree_through_the_trait() {
        let (exe, g, pg, store, x) = setup(ZooModel::B1);
        let input = EngineInput { graph: &g, partitioned: &pg, store: &store, x: &x };
        let hw = HwConfig::functional_tiles();
        let mut outputs = Vec::new();
        for engine in default_engines(&hw).iter_mut() {
            let p = engine.run(&exe, Some(&input)).unwrap();
            assert!(p.latency_s >= 0.0, "{}: negative latency", p.engine);
            assert!(p.kernel_launches > 0, "{}: no launches", p.engine);
            if let Some(out) = p.output {
                outputs.push((p.engine, out));
            }
        }
        // Exactly the two functional substrates produce numerics...
        assert_eq!(outputs.len(), 2);
        let (a, b) = (&outputs[0], &outputs[1]);
        assert_eq!((a.0, b.0), ("golden", "functional"));
        assert_eq!(a.1.len(), b.1.len());
        // ...and they agree on the same compiled program.
        let err = a.1.iter().zip(&b.1).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
        assert!(err < 1e-3, "golden vs functional max err {err}");
    }

    #[test]
    fn dynamic_remap_preserves_golden_equivalence() {
        // A dense single-tile graph (d ~ 0.33, far above the dense_hi
        // threshold): dynamic re-mapping must actually trigger, and the
        // re-mapped numerics must still match the golden reference.
        let meta = GraphMeta::new("dense", 96, 3000, 32, 4);
        let g = rmat_edges(meta, Default::default(), 11).gcn_normalized();
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        for model in [ZooModel::B1, ZooModel::B5] {
            let ir = model.build(g.meta.clone());
            let exe = compile(
                &ir,
                &pg.tile_counts(),
                &hw,
                crate::compiler::CompileOptions::default(),
            );
            assert!(exe.program.thresholds.is_some());
            let store = WeightStore::deterministic(&exe.ir, 33);
            let x = g.random_features(5);
            let input = EngineInput { graph: &g, partitioned: &pg, store: &store, x: &x };
            let golden = GoldenEngine.run(&exe, Some(&input)).unwrap();
            let mut fe = FunctionalEngine::default();
            fe.set_dynamic_remap(true);
            let dynp = fe.run(&exe, Some(&input)).unwrap();
            assert!(dynp.remaps > 0, "{}: dense tiles must re-map", exe.ir.name);
            let (a, b) = (golden.output.as_ref().unwrap(), dynp.output.as_ref().unwrap());
            assert_eq!(a.len(), b.len());
            let scale = a.iter().fold(1f32, |m, v| m.max(v.abs()));
            let err = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
            assert!(
                err <= 1e-3 * scale.max(1.0),
                "{}: dynamic vs golden max err {err} (scale {scale})",
                exe.ir.name
            );
            // The static functional path reports no re-maps on the same
            // executable.
            let statp = FunctionalEngine::default().run(&exe, Some(&input)).unwrap();
            assert_eq!(statp.remaps, 0);
            // And the dynamic cycle model is never slower than static.
            let mut se = SimEngine::new(HwConfig::alveo_u250());
            let stat_sim = se.run(&exe, None).unwrap();
            se.set_dynamic_remap(true);
            let dyn_sim = se.run(&exe, None).unwrap();
            assert!(dyn_sim.cycles <= stat_sim.cycles);
        }
    }

    #[test]
    fn sim_engine_is_deterministic_and_data_free() {
        let (exe, ..) = setup(ZooModel::B7);
        let mut e = SimEngine::new(HwConfig::alveo_u250());
        assert!(e.deterministic());
        let p1 = e.run(&exe, None).unwrap();
        let p2 = e.run(&exe, None).unwrap();
        assert_eq!(p1, p2);
        assert!(p1.cycles > 0 && p1.latency_s > 0.0 && p1.bytes_moved > 0);
        assert!(p1.output.is_none());
    }

    #[test]
    fn functional_engines_reject_missing_data() {
        let (exe, ..) = setup(ZooModel::B1);
        assert!(GoldenEngine.run(&exe, None).is_err());
        assert!(FunctionalEngine::default().run(&exe, None).is_err());
        assert!(SimEngine::new(HwConfig::alveo_u250()).run(&exe, None).is_ok());
    }

    #[test]
    fn prepare_packs_weights_and_runs_reuse_the_arena() {
        let (exe, g, pg, store, x) = setup(ZooModel::B1);
        let input = EngineInput { graph: &g, partitioned: &pg, store: &store, x: &x };
        let mut fe = FunctionalEngine::default();
        assert!(!fe.prepared());
        // Preparing without data is an error; with data it packs.
        assert!(fe.prepare(&exe, None).is_err());
        fe.prepare(&exe, Some(&input)).unwrap();
        assert!(fe.prepared());
        let p1 = fe.run(&exe, Some(&input)).unwrap();
        let cold_fresh = fe.arena_stats().fresh;
        let p2 = fe.run(&exe, Some(&input)).unwrap();
        assert_eq!(p1.output, p2.output, "steady-state run changed numerics");
        // Zero-alloc steady state through the trait: a warm run draws
        // every tile buffer from the engine's arena (<= 1 fresh buffer,
        // replacing the output matrix that escaped to the caller).
        let warm_fresh = fe.arena_stats().fresh - cold_fresh;
        assert!(warm_fresh <= 1, "warm engine run allocated {warm_fresh} buffers");
    }

    #[test]
    fn functional_engine_rejects_mismatched_partition() {
        let (exe, g, _, store, x) = setup(ZooModel::B1);
        // A different N1 — and, separately, a different N2 at the same
        // N1 — must both be rejected before any tile is sliced.
        for cfg in [
            PartitionConfig { n1: 64, n2: exe.cfg.n2 },
            PartitionConfig { n1: exe.cfg.n1, n2: exe.cfg.n2 * 2 },
        ] {
            let other = PartitionedGraph::build(&g, cfg);
            let input =
                EngineInput { graph: &g, partitioned: &other, store: &store, x: &x };
            assert!(
                FunctionalEngine::default().run(&exe, Some(&input)).is_err(),
                "{cfg:?} must be rejected"
            );
        }
    }
}
