//! Functional inference over a streaming graph: the numerics-producing
//! counterpart of the serving fleet's update path.
//!
//! A [`StreamingSession`] owns a [`DynamicGraph`] and a
//! [`FunctionalEngine`]. Applying an [`UpdateBatch`] seals a new epoch
//! through the incremental (dirty-subshard-only) repartition;
//! [`StreamingSession::infer`] then compiles the requested model
//! against that epoch's live tile counts (memoized per `(model,
//! epoch)`), exports the incrementally maintained partition once per
//! epoch, and runs real numerics through the warm functional engine.
//!
//! Because the exported partition is bit-identical to a from-scratch
//! [`crate::graph::PartitionedGraph::build`] of the materialized epoch
//! (the `stream` module's core invariant), the outputs are bit-identical
//! to recompiling and re-partitioning everything from zero — which is
//! exactly what `rust/tests/streaming.rs` pins across the model zoo.

use crate::compiler::{compile, CompileOptions, Executable};
use crate::config::HwConfig;
use crate::engine::{EngineInput, ExecProfile, FunctionalEngine, InferenceEngine};
use crate::exec::WeightStore;
use crate::graph::{CooGraph, PartitionConfig, PartitionedGraph};
use crate::ir::ZooModel;
use crate::stream::{ApplyReport, DynamicGraph, UpdateBatch};
use anyhow::Result;
use std::collections::HashMap;

/// Apply-and-infer session over one streaming graph.
pub struct StreamingSession {
    hw: HwConfig,
    weight_seed: u64,
    pub dyng: DynamicGraph,
    engine: FunctionalEngine,
    /// Compiled executables per (model, epoch).
    exes: HashMap<(ZooModel, u32), Executable>,
    /// The current epoch's materialized graph + exported partition,
    /// rebuilt lazily once per epoch.
    snap: Option<(u32, CooGraph, PartitionedGraph)>,
}

impl StreamingSession {
    /// Start a session at epoch 0 of `g`, partitioned for `hw`'s tile
    /// shape. `weight_seed` feeds [`WeightStore::deterministic`] — the
    /// same seed yields the same weights at every epoch (layer shapes
    /// do not depend on graph size), so cross-epoch output drift is
    /// purely the graph churn.
    pub fn new(g: CooGraph, hw: HwConfig, weight_seed: u64) -> StreamingSession {
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        StreamingSession {
            dyng: DynamicGraph::new(g, cfg),
            hw,
            weight_seed,
            engine: FunctionalEngine::default(),
            exes: HashMap::new(),
            snap: None,
        }
    }

    pub fn epoch(&self) -> u32 {
        self.dyng.epoch()
    }

    /// Enable density-aware dynamic kernel re-mapping on the underlying
    /// functional engine.
    pub fn set_dynamic_remap(&mut self, enabled: bool) {
        self.engine.set_dynamic_remap(enabled);
    }

    /// Apply one update batch (incremental repartition inside) and
    /// invalidate the per-epoch snapshot. Executables of now-sealed
    /// older epochs are unreachable (`infer` always compiles the
    /// current epoch) and are dropped so a long stream does not grow
    /// one dead program per (model, epoch).
    pub fn apply(&mut self, batch: &UpdateBatch) -> ApplyReport {
        self.snap = None;
        let report = self.dyng.apply(batch);
        self.exes.retain(|&(_, e), _| e >= report.epoch);
        report
    }

    /// The current epoch's materialized graph (refreshing the snapshot
    /// if an update sealed a newer epoch).
    pub fn graph(&mut self) -> &CooGraph {
        self.refresh();
        &self.snap.as_ref().unwrap().1
    }

    fn refresh(&mut self) {
        let e = self.dyng.epoch();
        let stale = match &self.snap {
            Some((se, _, _)) => *se != e,
            None => true,
        };
        if stale {
            let g = self.dyng.materialize(e);
            let pg = self.dyng.export_partitioned();
            self.snap = Some((e, g, pg));
        }
    }

    /// Run `model` over the current epoch with input features `x`
    /// (row-major, `n_vertices × feat_len` — the caller extends rows
    /// when vertices are added). Compiles at most once per (model,
    /// epoch).
    pub fn infer(&mut self, model: ZooModel, x: &[f32]) -> Result<ExecProfile> {
        self.refresh();
        let key = (model, self.dyng.epoch());
        let snap = &self.snap;
        let hw = &self.hw;
        let exe: &Executable = self.exes.entry(key).or_insert_with(|| {
            let (_, g, pg) = snap.as_ref().expect("refreshed above");
            let ir = model.build(g.meta.clone());
            let tiles = pg.tile_counts();
            compile(&ir, &tiles, hw, CompileOptions::default())
        });
        let (_, g, pg) = self.snap.as_ref().expect("refreshed above");
        let store = WeightStore::deterministic(&exe.ir, self.weight_seed);
        let input = EngineInput { graph: g, partitioned: pg, store: &store, x };
        self.engine.run(exe, Some(&input))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::rmat_edges;
    use crate::graph::GraphMeta;
    use crate::stream::{ChurnGenerator, ChurnSpec};

    #[test]
    fn infer_apply_infer_tracks_the_churn() {
        let meta = GraphMeta::new("t", 300, 1500, 16, 4);
        let g = rmat_edges(meta, Default::default(), 9).gcn_normalized();
        let hw = HwConfig::functional_tiles();
        let mut s = StreamingSession::new(g, hw, 33);
        let x = s.graph().random_features(5);
        let p0 = s.infer(ZooModel::B1, &x).unwrap();
        let p0_again = s.infer(ZooModel::B1, &x).unwrap();
        assert_eq!(p0.output, p0_again.output, "same epoch, same outputs");
        let mut gen = ChurnGenerator::new(Default::default(), 3);
        let batch =
            gen.next_batch(&s.dyng, ChurnSpec { inserts: 40, deletes: 10, new_vertices: 0 });
        let r = s.apply(&batch);
        assert_eq!(r.epoch, 1);
        assert!(r.dirty_subshards > 0);
        let p1 = s.infer(ZooModel::B1, &x).unwrap();
        assert_ne!(p0.output, p1.output, "churn must change the numerics");
        // The incremental epoch-1 output is bit-identical to a cold
        // session rebuilt from the materialized epoch-1 graph.
        let cold_g = s.dyng.materialize(1);
        let mut cold = StreamingSession::new(cold_g, HwConfig::functional_tiles(), 33);
        let p1_cold = cold.infer(ZooModel::B1, &x).unwrap();
        assert_eq!(p1.output, p1_cold.output);
    }
}
