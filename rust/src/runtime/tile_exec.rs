//! [`PjrtBackend`] — a [`TileBackend`] that executes each tile on the
//! AOT-compiled Pallas/JAX kernels. Tiles are padded to the fixed
//! artifact shapes (zero padding is exact for GEMM/SpDMM-sum/VecAdd;
//! SpDMM-max and SDDMM mask via the `n_valid` operand).

use super::client::{ArgValue, PjrtRuntime};
use crate::exec::TileBackend;
use crate::graph::CsrSubshard;
use crate::isa::AggOp;

/// Artifact tile geometry (must match python/compile/aot.py TILE_*).
#[derive(Clone, Copy, Debug)]
pub struct TileGeom {
    pub n: usize,
    pub f: usize,
    pub e: usize,
}

/// PJRT-backed tile executor.
pub struct PjrtBackend<'rt> {
    rt: &'rt PjrtRuntime,
    geom: TileGeom,
    gemm_name: String,
    spdmm_name: String,
    spdmm_max_name: String,
    sddmm_name: String,
    vecadd_name: String,
    /// Number of kernel launches (for reporting).
    pub launches: u64,
}

impl<'rt> PjrtBackend<'rt> {
    /// Resolve artifact names from the manifest (by prefix) and parse the
    /// geometry out of the spdmm artifact name `spdmm_e{E}_n{N}_f{F}`.
    pub fn new(rt: &'rt PjrtRuntime) -> anyhow::Result<PjrtBackend<'rt>> {
        let m = rt.manifest();
        let spdmm = m
            .find_prefix("spdmm_e")
            .ok_or_else(|| anyhow::anyhow!("no spdmm artifact"))?
            .to_string();
        let nums: Vec<usize> = spdmm
            .split(['e', 'n', 'f', '_'])
            .filter_map(|t| t.parse().ok())
            .collect();
        anyhow::ensure!(nums.len() == 3, "cannot parse geometry from {spdmm}");
        let geom = TileGeom { e: nums[0], n: nums[1], f: nums[2] };
        let need = |p: &str| -> anyhow::Result<String> {
            Ok(m.find_prefix(p)
                .ok_or_else(|| anyhow::anyhow!("no artifact with prefix {p}"))?
                .to_string())
        };
        Ok(PjrtBackend {
            rt,
            geom,
            gemm_name: need("gemm_1")?, // "gemm_{M}x{K}x{N}" (plain, no act)
            spdmm_name: spdmm,
            spdmm_max_name: need("spdmm_max_e")?,
            sddmm_name: need("sddmm_e")?,
            vecadd_name: need("vecadd_")?,
            launches: 0,
        })
    }

    pub fn geom(&self) -> TileGeom {
        self.geom
    }

    fn pad2(&self, buf: &[f32], rows: usize, cols: usize, pr: usize, pc: usize) -> Vec<f32> {
        debug_assert!(rows <= pr && cols <= pc, "tile {rows}x{cols} > pad {pr}x{pc}");
        let mut out = vec![0f32; pr * pc];
        for r in 0..rows {
            out[r * pc..r * pc + cols].copy_from_slice(&buf[r * cols..(r + 1) * cols]);
        }
        out
    }

    fn unpad2(&self, buf: &[f32], rows: usize, cols: usize, pc: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            out.extend_from_slice(&buf[r * pc..r * pc + cols]);
        }
        out
    }

    /// The AOT artifacts consume COO edge streams; rebuild the
    /// subshard's slot-ordered COO (local src/dst plus live weights
    /// gathered through `perm`) from the CSR index.
    fn coo_of(csr: &CsrSubshard, ew: Option<&[f32]>) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
        let nnz = csr.nnz();
        let mut src = vec![0u32; nnz];
        let mut dst = vec![0u32; nnz];
        let mut w = vec![0f32; nnz];
        let mut at = 0;
        for r in 0..csr.rows as usize {
            for slot in csr.row(r) {
                src[at] = csr.cols[slot];
                dst[at] = r as u32;
                if let Some(ew) = ew {
                    w[at] = ew[csr.perm[slot] as usize];
                }
                at += 1;
            }
        }
        (src, dst, w)
    }
}

impl<'rt> TileBackend for PjrtBackend<'rt> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn gemm(
        &mut self,
        h: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        b: &[f32],
        out: &mut [f32],
    ) {
        let g = self.geom;
        // Artifact is (N x F) @ (F x F): pad m->N, k->F, n->F.
        let hp = self.pad2(h, m, k, g.n, g.f);
        let wp = self.pad2(w, k, n, g.f, g.f);
        let mut bp = vec![0f32; g.f];
        bp[..n].copy_from_slice(b);
        self.launches += 1;
        let padded = self
            .rt
            .execute(
                &self.gemm_name,
                &[ArgValue::F32(&hp), ArgValue::F32(&wp), ArgValue::F32(&bp)],
            )
            .expect("pjrt gemm");
        out.copy_from_slice(&self.unpad2(&padded, m, n, g.f));
    }

    fn spdmm_csr(
        &mut self,
        csr: &CsrSubshard,
        ew: &[f32],
        h: &[f32],
        f: usize,
        aggop: AggOp,
        acc: &mut [f32],
        touched: &mut [u32],
    ) {
        let g = self.geom;
        let name = match aggop {
            AggOp::Sum | AggOp::Mean => &self.spdmm_name,
            AggOp::Max => &self.spdmm_max_name,
            AggOp::Min => panic!("min aggregation has no AOT artifact (use RustBackend)"),
        };
        let n_out = csr.rows as usize;
        let n_in = h.len() / f.max(1);
        let (src, dst, w) = Self::coo_of(csr, Some(ew));
        let hp = self.pad2(h, n_in, f, g.n, g.f);
        // `acc` arrives neutral-initialized (or holding earlier
        // subshards' partials); combine each chunk in — on Max/Min only
        // the chunk's touched rows, since chunk partials pad untouched
        // rows with 0, which would clobber negative maxima/minima.
        for chunk in src
            .chunks(g.e)
            .zip(dst.chunks(g.e))
            .zip(w.chunks(g.e))
            .map(|((s, d), w)| (s, d, w))
        {
            let (s, d, w) = chunk;
            let mut si = vec![0i32; g.e];
            let mut di = vec![0i32; g.e];
            let mut wi = vec![0f32; g.e];
            for (i, ((&a, &b), &c)) in s.iter().zip(d).zip(w).enumerate() {
                si[i] = a as i32;
                di[i] = b as i32;
                wi[i] = c;
            }
            let nv = [s.len() as i32];
            self.launches += 1;
            let part = self
                .rt
                .execute(
                    name,
                    &[
                        ArgValue::I32(&si),
                        ArgValue::I32(&di),
                        ArgValue::F32(&wi),
                        ArgValue::I32(&nv),
                        ArgValue::F32(&hp),
                    ],
                )
                .expect("pjrt spdmm");
            let part = self.unpad2(&part, n_out, f, g.f);
            match aggop {
                AggOp::Sum | AggOp::Mean => {
                    for (o, &p) in acc.iter_mut().zip(&part) {
                        *o += p;
                    }
                }
                AggOp::Max | AggOp::Min => {
                    for &di in d {
                        let r = di as usize;
                        for c in 0..f {
                            let o = &mut acc[r * f + c];
                            let p = part[r * f + c];
                            *o = if aggop == AggOp::Max { o.max(p) } else { o.min(p) };
                        }
                    }
                }
            }
            for &di in d {
                touched[di as usize] = 1;
            }
        }
    }

    fn sddmm_csr(&mut self, csr: &CsrSubshard, hl: &[f32], hr: &[f32], f: usize, vals: &mut [f32]) {
        let g = self.geom;
        let n_l = hl.len() / f.max(1);
        let n_r = hr.len() / f.max(1);
        let (src, dst, _) = Self::coo_of(csr, None);
        let hlp = self.pad2(hl, n_l, f, g.n, g.f);
        let hrp = self.pad2(hr, n_r, f, g.n, g.f);
        let mut at = 0;
        for (s, d) in src.chunks(g.e).zip(dst.chunks(g.e)) {
            let mut si = vec![0i32; g.e];
            let mut di = vec![0i32; g.e];
            for (i, (&a, &b)) in s.iter().zip(d).enumerate() {
                si[i] = a as i32;
                di[i] = b as i32;
            }
            let nv = [s.len() as i32];
            self.launches += 1;
            let chunk_vals = self
                .rt
                .execute(
                    &self.sddmm_name,
                    &[
                        ArgValue::I32(&si),
                        ArgValue::I32(&di),
                        ArgValue::I32(&nv),
                        ArgValue::F32(&hlp),
                        ArgValue::F32(&hrp),
                    ],
                )
                .expect("pjrt sddmm");
            vals[at..at + s.len()].copy_from_slice(&chunk_vals[..s.len()]);
            at += s.len();
        }
    }

    fn vecadd(&mut self, a: &[f32], b: &[f32], out: &mut [f32]) {
        let g = self.geom;
        // Flatten-agnostic: pad the flat buffer into (N x F) tiles.
        debug_assert_eq!(a.len(), b.len());
        let total = a.len();
        let per_tile = g.n * g.f;
        let mut at = 0;
        while at < total {
            let take = (total - at).min(per_tile);
            let mut ap = vec![0f32; per_tile];
            let mut bp = vec![0f32; per_tile];
            ap[..take].copy_from_slice(&a[at..at + take]);
            bp[..take].copy_from_slice(&b[at..at + take]);
            self.launches += 1;
            let o = self
                .rt
                .execute(&self.vecadd_name, &[ArgValue::F32(&ap), ArgValue::F32(&bp)])
                .expect("pjrt vecadd");
            out[at..at + take].copy_from_slice(&o[..take]);
            at += take;
        }
    }
}

#[cfg(test)]
mod tests {


    #[test]
    fn geometry_parse() {
        // Parsing "spdmm_e1024_n128_f64" -> e=1024, n=128, f=64 happens in
        // PjrtBackend::new; replicate the split logic here.
        let nums: Vec<usize> = "spdmm_e1024_n128_f64"
            .split(['e', 'n', 'f', '_'])
            .filter_map(|t| t.parse().ok())
            .collect();
        assert_eq!(nums, vec![1024, 128, 64]);
    }
}
