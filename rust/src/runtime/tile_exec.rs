//! [`PjrtBackend`] — a [`TileBackend`] that executes each tile on the
//! AOT-compiled Pallas/JAX kernels. Tiles are padded to the fixed
//! artifact shapes (zero padding is exact for GEMM/SpDMM-sum/VecAdd;
//! SpDMM-max and SDDMM mask via the `n_valid` operand).

use super::client::{ArgValue, PjrtRuntime};
use crate::exec::TileBackend;
use crate::isa::AggOp;

/// Artifact tile geometry (must match python/compile/aot.py TILE_*).
#[derive(Clone, Copy, Debug)]
pub struct TileGeom {
    pub n: usize,
    pub f: usize,
    pub e: usize,
}

/// PJRT-backed tile executor.
pub struct PjrtBackend<'rt> {
    rt: &'rt PjrtRuntime,
    geom: TileGeom,
    gemm_name: String,
    spdmm_name: String,
    spdmm_max_name: String,
    sddmm_name: String,
    vecadd_name: String,
    /// Number of kernel launches (for reporting).
    pub launches: u64,
}

impl<'rt> PjrtBackend<'rt> {
    /// Resolve artifact names from the manifest (by prefix) and parse the
    /// geometry out of the spdmm artifact name `spdmm_e{E}_n{N}_f{F}`.
    pub fn new(rt: &'rt PjrtRuntime) -> anyhow::Result<PjrtBackend<'rt>> {
        let m = rt.manifest();
        let spdmm = m
            .find_prefix("spdmm_e")
            .ok_or_else(|| anyhow::anyhow!("no spdmm artifact"))?
            .to_string();
        let nums: Vec<usize> = spdmm
            .split(['e', 'n', 'f', '_'])
            .filter_map(|t| t.parse().ok())
            .collect();
        anyhow::ensure!(nums.len() == 3, "cannot parse geometry from {spdmm}");
        let geom = TileGeom { e: nums[0], n: nums[1], f: nums[2] };
        let need = |p: &str| -> anyhow::Result<String> {
            Ok(m.find_prefix(p)
                .ok_or_else(|| anyhow::anyhow!("no artifact with prefix {p}"))?
                .to_string())
        };
        Ok(PjrtBackend {
            rt,
            geom,
            gemm_name: need("gemm_1")?, // "gemm_{M}x{K}x{N}" (plain, no act)
            spdmm_name: spdmm,
            spdmm_max_name: need("spdmm_max_e")?,
            sddmm_name: need("sddmm_e")?,
            vecadd_name: need("vecadd_")?,
            launches: 0,
        })
    }

    pub fn geom(&self) -> TileGeom {
        self.geom
    }

    fn pad2(&self, buf: &[f32], rows: usize, cols: usize, pr: usize, pc: usize) -> Vec<f32> {
        debug_assert!(rows <= pr && cols <= pc, "tile {rows}x{cols} > pad {pr}x{pc}");
        let mut out = vec![0f32; pr * pc];
        for r in 0..rows {
            out[r * pc..r * pc + cols].copy_from_slice(&buf[r * cols..(r + 1) * cols]);
        }
        out
    }

    fn unpad2(&self, buf: &[f32], rows: usize, cols: usize, pc: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            out.extend_from_slice(&buf[r * pc..r * pc + cols]);
        }
        out
    }
}

impl<'rt> TileBackend for PjrtBackend<'rt> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn gemm(&mut self, h: &[f32], m: usize, k: usize, w: &[f32], n: usize, b: &[f32])
        -> Vec<f32> {
        let g = self.geom;
        // Artifact is (N x F) @ (F x F): pad m->N, k->F, n->F.
        let hp = self.pad2(h, m, k, g.n, g.f);
        let wp = self.pad2(w, k, n, g.f, g.f);
        let mut bp = vec![0f32; g.f];
        bp[..n].copy_from_slice(b);
        self.launches += 1;
        let out = self
            .rt
            .execute(
                &self.gemm_name,
                &[ArgValue::F32(&hp), ArgValue::F32(&wp), ArgValue::F32(&bp)],
            )
            .expect("pjrt gemm");
        self.unpad2(&out, m, n, g.f)
    }

    fn spdmm(
        &mut self,
        src: &[u32],
        dst: &[u32],
        ew: &[f32],
        h: &[f32],
        n_in: usize,
        f: usize,
        n_out: usize,
        aggop: AggOp,
    ) -> Vec<f32> {
        let g = self.geom;
        let name = match aggop {
            AggOp::Sum | AggOp::Mean => &self.spdmm_name,
            AggOp::Max => &self.spdmm_max_name,
            AggOp::Min => panic!("min aggregation has no AOT artifact (use RustBackend)"),
        };
        let hp = self.pad2(h, n_in, f, g.n, g.f);
        // Neutral init + touched-row combine: chunk partials have 0 for
        // untouched rows, which would clobber negative maxima/minima.
        let neutral = match aggop {
            AggOp::Sum | AggOp::Mean => 0.0f32,
            AggOp::Max => f32::NEG_INFINITY,
            AggOp::Min => f32::INFINITY,
        };
        let mut out = vec![neutral; n_out * f];
        let mut touched = vec![false; n_out];
        // Edge stream in artifact-sized chunks.
        for chunk in src
            .chunks(g.e)
            .zip(dst.chunks(g.e))
            .zip(ew.chunks(g.e))
            .map(|((s, d), w)| (s, d, w))
        {
            let (s, d, w) = chunk;
            let mut si = vec![0i32; g.e];
            let mut di = vec![0i32; g.e];
            let mut wi = vec![0f32; g.e];
            for (i, ((&a, &b), &c)) in s.iter().zip(d).zip(w).enumerate() {
                si[i] = a as i32;
                di[i] = b as i32;
                wi[i] = c;
            }
            let nv = [s.len() as i32];
            self.launches += 1;
            let part = self
                .rt
                .execute(
                    name,
                    &[
                        ArgValue::I32(&si),
                        ArgValue::I32(&di),
                        ArgValue::F32(&wi),
                        ArgValue::I32(&nv),
                        ArgValue::F32(&hp),
                    ],
                )
                .expect("pjrt spdmm");
            let part = self.unpad2(&part, n_out, f, g.f);
            match aggop {
                AggOp::Sum | AggOp::Mean => {
                    for (o, &p) in out.iter_mut().zip(&part) {
                        *o += p;
                    }
                }
                AggOp::Max | AggOp::Min => {
                    for &di in d {
                        let r = di as usize;
                        for c in 0..f {
                            let o = &mut out[r * f + c];
                            let p = part[r * f + c];
                            *o = if aggop == AggOp::Max { o.max(p) } else { o.min(p) };
                        }
                    }
                }
            }
            for &di in d {
                touched[di as usize] = true;
            }
        }
        // Untouched rows -> 0 (kernel convention).
        if neutral != 0.0 {
            for (r, t) in touched.iter().enumerate() {
                if !*t {
                    for c in 0..f {
                        out[r * f + c] = 0.0;
                    }
                }
            }
        }
        out
    }

    fn sddmm(
        &mut self,
        src: &[u32],
        dst: &[u32],
        hl: &[f32],
        hr: &[f32],
        n_l: usize,
        n_r: usize,
        f: usize,
    ) -> Vec<f32> {
        let g = self.geom;
        let hlp = self.pad2(hl, n_l, f, g.n, g.f);
        let hrp = self.pad2(hr, n_r, f, g.n, g.f);
        let mut out = Vec::with_capacity(src.len());
        for (s, d) in src.chunks(g.e).zip(dst.chunks(g.e)) {
            let mut si = vec![0i32; g.e];
            let mut di = vec![0i32; g.e];
            for (i, (&a, &b)) in s.iter().zip(d).enumerate() {
                si[i] = a as i32;
                di[i] = b as i32;
            }
            let nv = [s.len() as i32];
            self.launches += 1;
            let vals = self
                .rt
                .execute(
                    &self.sddmm_name,
                    &[
                        ArgValue::I32(&si),
                        ArgValue::I32(&di),
                        ArgValue::I32(&nv),
                        ArgValue::F32(&hlp),
                        ArgValue::F32(&hrp),
                    ],
                )
                .expect("pjrt sddmm");
            out.extend_from_slice(&vals[..s.len()]);
        }
        out
    }

    fn vecadd(&mut self, a: &[f32], b: &[f32]) -> Vec<f32> {
        let g = self.geom;
        // Flatten-agnostic: process in tile-sized row groups of width f.
        debug_assert_eq!(a.len(), b.len());
        // Treat as (len/f') rows where f' divides len; simplest: pad the
        // flat buffer into (N x F) tiles.
        let total = a.len();
        let per_tile = g.n * g.f;
        let mut out = Vec::with_capacity(total);
        let mut at = 0;
        while at < total {
            let take = (total - at).min(per_tile);
            let mut ap = vec![0f32; per_tile];
            let mut bp = vec![0f32; per_tile];
            ap[..take].copy_from_slice(&a[at..at + take]);
            bp[..take].copy_from_slice(&b[at..at + take]);
            self.launches += 1;
            let o = self
                .rt
                .execute(&self.vecadd_name, &[ArgValue::F32(&ap), ArgValue::F32(&bp)])
                .expect("pjrt vecadd");
            out.extend_from_slice(&o[..take]);
            at += take;
        }
        out
    }
}

#[cfg(test)]
mod tests {


    #[test]
    fn geometry_parse() {
        // Parsing "spdmm_e1024_n128_f64" -> e=1024, n=128, f=64 happens in
        // PjrtBackend::new; replicate the split logic here.
        let nums: Vec<usize> = "spdmm_e1024_n128_f64"
            .split(['e', 'n', 'f', '_'])
            .filter_map(|t| t.parse().ok())
            .collect();
        assert_eq!(nums, vec![1024, 128, 64]);
    }
}
