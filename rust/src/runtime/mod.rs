//! The PJRT functional runtime: loads the AOT HLO-text artifacts produced
//! once at build time by `python/compile/aot.py` (L2 JAX calling the L1
//! Pallas kernels) and executes them from rust. Python is never on this
//! path — the binary is self-contained once `artifacts/` exists.
//!
//! * [`artifacts`] — manifest parsing and artifact discovery,
//! * [`client`] — the `xla` crate wrapper: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → compile → execute,
//! * [`tile_exec`] — a [`crate::exec::TileBackend`] that pads tiles to
//!   the artifact shapes and runs them on the compiled kernels.

pub mod artifacts;
pub mod client;
pub mod tile_exec;

pub use artifacts::{find_artifacts_dir, Manifest};
pub use client::{client_args, ArgValue, PjrtRuntime};
pub use tile_exec::PjrtBackend;
