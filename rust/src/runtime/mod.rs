//! The PJRT functional runtime: loads the AOT HLO-text artifacts produced
//! once at build time by `python/compile/aot.py` (L2 JAX calling the L1
//! Pallas kernels) and executes them from rust. Python is never on this
//! path — the binary is self-contained once `artifacts/` exists.
//!
//! * [`artifacts`] — manifest parsing and artifact discovery,
//! * `client` — the `xla` crate wrapper: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → compile → execute,
//! * `tile_exec` — a [`crate::exec::TileBackend`] that pads tiles to
//!   the artifact shapes and runs them on the compiled kernels.
//!
//! The PJRT client needs the `xla` crate, which is not in the offline
//! vendor set; `client`/`tile_exec` are therefore behind the `pjrt`
//! feature (see Cargo.toml). Artifact discovery stays always-on so the
//! CLI can report whether `make artifacts` has run.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod tile_exec;

pub use artifacts::{find_artifacts_dir, Manifest};
#[cfg(feature = "pjrt")]
pub use client::{client_args, ArgValue, PjrtRuntime};
#[cfg(feature = "pjrt")]
pub use tile_exec::PjrtBackend;
