//! PJRT client wrapper: compile HLO-text artifacts once, execute many
//! times from the coordinator's hot path.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and DESIGN.md).

use super::artifacts::{ArgSpec, Dtype, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded runtime: one PJRT CPU client plus every compiled artifact.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// An argument value for execution.
pub enum ArgValue<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl PjrtRuntime {
    /// Create the CPU client and eagerly compile every artifact in the
    /// manifest (compile once, execute many).
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
        let manifest = Manifest::load(dir)?;
        let mut rt = PjrtRuntime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            executables: HashMap::new(),
        };
        let names: Vec<String> =
            rt.manifest.entries.iter().map(|(n, _)| n.clone()).collect();
        for name in names {
            rt.compile(&name)?;
        }
        Ok(rt)
    }

    fn compile(&mut self, name: &str) -> Result<()> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name` with the given arguments (shapes must
    /// match the manifest; `f32` outputs are returned flattened).
    pub fn execute(&self, name: &str, args: &[ArgValue]) -> Result<Vec<f32>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact {name} not loaded"))?;
        let specs = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))?;
        if specs.len() != args.len() {
            anyhow::bail!("{name}: expected {} args, got {}", specs.len(), args.len());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (spec, arg) in specs.iter().zip(args) {
            literals.push(to_literal(spec, arg)?);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {name}: {e:?}"))
    }
}

/// Terse [`ArgValue`] constructors for call sites.
pub mod client_args {
    use super::ArgValue;

    pub fn f32s(v: &[f32]) -> ArgValue<'_> {
        ArgValue::F32(v)
    }

    pub fn i32s(v: &[i32]) -> ArgValue<'_> {
        ArgValue::I32(v)
    }
}

fn to_literal(spec: &ArgSpec, arg: &ArgValue) -> Result<xla::Literal> {
    let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
    let lit = match (spec.dtype, arg) {
        (Dtype::F32, ArgValue::F32(v)) => {
            if v.len() != spec.numel() {
                anyhow::bail!("f32 arg has {} elems, want {}", v.len(), spec.numel());
            }
            xla::Literal::vec1(v)
        }
        (Dtype::I32, ArgValue::I32(v)) => {
            if v.len() != spec.numel() {
                anyhow::bail!("i32 arg has {} elems, want {}", v.len(), spec.numel());
            }
            xla::Literal::vec1(v)
        }
        _ => anyhow::bail!("dtype mismatch"),
    };
    if spec.dims.len() == 1 {
        Ok(lit)
    } else {
        lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
    }
}
