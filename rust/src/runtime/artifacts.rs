//! Artifact discovery: `manifest.txt` maps artifact names to their
//! argument signatures (`name f32[128,64] i32[1024] ...`), written by
//! `python/compile/aot.py` alongside the `*.hlo.txt` files.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One argument of an artifact entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgSpec {
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<(String, Vec<ArgSpec>)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().context("empty manifest line")?.to_string();
            let mut args = Vec::new();
            for tok in parts {
                args.push(parse_arg(tok).with_context(|| format!("entry {name}"))?);
            }
            if args.is_empty() {
                bail!("artifact {name} has no arguments");
            }
            entries.push((name, args));
        }
        Ok(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        Manifest::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&[ArgSpec]> {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, a)| a.as_slice())
    }

    /// Find an artifact by prefix (e.g. "spdmm_e" matches
    /// "spdmm_e1024_n128_f64").
    pub fn find_prefix(&self, prefix: &str) -> Option<&str> {
        self.entries
            .iter()
            .map(|(n, _)| n.as_str())
            .find(|n| n.starts_with(prefix))
    }
}

fn parse_arg(tok: &str) -> Result<ArgSpec> {
    let (dt, rest) = tok.split_once('[').context("missing [")?;
    let dtype = match dt {
        "f32" => Dtype::F32,
        "i32" => Dtype::I32,
        other => bail!("unknown dtype {other}"),
    };
    let dims_s = rest.strip_suffix(']').context("missing ]")?;
    let dims = dims_s
        .split(',')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(ArgSpec { dtype, dims })
}

/// Locate the artifacts directory: $GRAPHAGILE_ARTIFACTS, else
/// ./artifacts relative to the working directory, else relative to the
/// crate root (so `cargo test` finds it from any cwd).
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("GRAPHAGILE_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    for base in [".", env!("CARGO_MANIFEST_DIR")] {
        let p = Path::new(base).join("artifacts");
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(
            "gemm_128x64x64 f32[128,64] f32[64,64] f32[64]\n\
             spdmm_e1024_n128_f64 i32[1024] i32[1024] f32[1024] i32[1] f32[128,64]\n",
        )
        .unwrap();
        assert_eq!(m.entries.len(), 2);
        let args = m.get("spdmm_e1024_n128_f64").unwrap();
        assert_eq!(args.len(), 5);
        assert_eq!(args[0].dtype, Dtype::I32);
        assert_eq!(args[4].dims, vec![128, 64]);
        assert_eq!(args[4].numel(), 128 * 64);
        assert_eq!(m.find_prefix("spdmm_e"), Some("spdmm_e1024_n128_f64"));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("name_only\n").is_err());
        assert!(Manifest::parse("x u8[3]\n").is_err());
        assert!(Manifest::parse("x f32[3\n").is_err());
    }

    #[test]
    fn finds_repo_artifacts() {
        // `make artifacts` has run in this repo; the manifest must parse.
        if let Some(dir) = find_artifacts_dir() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find_prefix("gemm_").is_some());
            assert!(m.find_prefix("spdmm_e").is_some());
            assert!(m.find_prefix("sddmm_e").is_some());
            assert!(m.find_prefix("vecadd_").is_some());
        }
    }
}
