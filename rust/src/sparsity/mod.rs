//! Density-aware dynamic kernel re-mapping (after Dynasparse, arXiv
//! 2303.12901 — the same group's follow-up to GraphAGILE).
//!
//! GraphAGILE's kernel-mapping pass (Sec. 6.6) picks GEMM vs SpDMM vs
//! SDDMM per layer from *static* whole-graph metadata. But the sparsity
//! that matters materializes at runtime: per-partition subgraphs and
//! intermediate feature matrices have densities that differ wildly from
//! the whole-graph average. This module moves the decision to run time:
//!
//! * **Profiler** — [`tile_density`] / [`adjacency_density`] compute the
//!   *exact* density of adjacency subshards from the Fiber-Shard tile
//!   counts; [`feature_density_estimates`] is the cheap analytic
//!   estimator for intermediate feature matrices (GEMM outputs are
//!   dense, ReLU halves density, aggregation fills rows at a rate set
//!   by the mean degree — no feature values are ever inspected).
//! * **Threshold table** — [`build_table`] turns the profile into a
//!   [`ThresholdTable`]: one *provisional* [`KernelMode`] per layer
//!   (exactly what the emitted instructions encode) plus the
//!   [`ThresholdTable::dense_hi`] / [`ThresholdTable::sparse_lo`]
//!   hysteresis band derived from the ACK's analytic break-even
//!   ([`break_even_density`]). The table is serialized into the `.ga`
//!   binary as the optional GA02 section (`isa::binary`).
//! * **Re-mapper** — [`choose_mode`] is the per-Tiling-Block runtime
//!   decision both the functional executor (`exec::functional`, real
//!   numerics through the dense path) and the cycle model (`sim::ack`,
//!   charging the re-mapped mode) consult through
//!   [`crate::engine::InferenceEngine::set_dynamic_remap`].
//!
//! The re-map never changes results — a densified subshard GEMM computes
//! exactly the weighted-sum aggregation SpDMM computes — so golden
//! equivalence holds regardless of which mode executes, and the cycle
//! model only accepts a re-map that it models as strictly cheaper, so
//! dynamic mapping is never slower than static.

use crate::graph::TileCounts;
use crate::ir::{LayerType, ModelIr};
use crate::isa::Activation;
use anyhow::{bail, Result};

/// Execution mode of one Tiling Block on the Adaptive Computation Kernel
/// (paper Sec. 5.4: the ACK reconfigures between these in one cycle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelMode {
    /// Dense systolic matrix multiply.
    Gemm = 0,
    /// Edge-centric sparse-dense multiply (aggregation).
    Spdmm = 1,
    /// Sampled dense-dense multiply (per-edge inner products).
    Sddmm = 2,
    /// Element-wise path (VectorAdd / Activation / BatchNorm).
    Eltwise = 3,
}

impl KernelMode {
    /// Wire encoding (one byte in the GA02 threshold section).
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Decode the wire byte; errors on unknown modes (corrupt binary).
    pub fn from_u8(v: u8) -> Result<KernelMode> {
        Ok(match v {
            0 => KernelMode::Gemm,
            1 => KernelMode::Spdmm,
            2 => KernelMode::Sddmm,
            3 => KernelMode::Eltwise,
            _ => bail!("bad kernel mode {v}"),
        })
    }
}

/// Per-layer row of the threshold table: the compiler's provisional
/// kernel choice plus the densities it was derived from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThresholdEntry {
    pub layer_id: u16,
    /// Compile-time kernel choice (what the emitted instructions encode).
    pub provisional: KernelMode,
    /// Analytic estimate of this layer's *input* feature density.
    pub feat_density: f32,
    /// Exact whole-graph adjacency density over non-empty subshards
    /// (0 for layers that never touch the adjacency).
    pub adj_density: f32,
}

/// The compiler-emitted re-mapping contract: provisional per-layer modes
/// plus the density band inside which the provisional choice stands.
/// Serialized as the optional GA02 section of the `.ga` binary.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ThresholdTable {
    /// At or above this tile density, a sparse-mapped (SpDMM) block is a
    /// candidate for dense GEMM re-mapping.
    pub dense_hi: f32,
    /// At or below this density, a dense-mapped (GEMM) block is a
    /// candidate for sparse re-mapping. Kept strictly below `dense_hi`
    /// so borderline tiles do not flip-flop (hysteresis).
    pub sparse_lo: f32,
    pub entries: Vec<ThresholdEntry>,
}

/// Bytes per serialized [`ThresholdEntry`]: u16 id + u8 mode + two f32.
pub const ENTRY_BYTES: usize = 11;

impl ThresholdTable {
    /// Table row for `layer_id`, if the compiler emitted one.
    pub fn entry(&self, layer_id: u16) -> Option<&ThresholdEntry> {
        self.entries.iter().find(|e| e.layer_id == layer_id)
    }

    /// Serialized size of the GA02 section body.
    pub fn size_bytes(&self) -> u64 {
        4 + 4 + 4 + (self.entries.len() * ENTRY_BYTES) as u64
    }

    /// Serialize the section body (two f32 thresholds, entry count,
    /// then the fixed-width entries).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.size_bytes() as usize);
        out.extend_from_slice(&self.dense_hi.to_le_bytes());
        out.extend_from_slice(&self.sparse_lo.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            out.extend_from_slice(&e.layer_id.to_le_bytes());
            out.push(e.provisional.as_u8());
            out.extend_from_slice(&e.feat_density.to_le_bytes());
            out.extend_from_slice(&e.adj_density.to_le_bytes());
        }
        out
    }

    /// Parse a section body from the front of `data`. Returns the table
    /// and the number of bytes consumed; errors (never panics) on
    /// truncated or corrupt input.
    pub fn from_bytes(data: &[u8]) -> Result<(ThresholdTable, usize)> {
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Result<&[u8]> {
            if *at + n > data.len() {
                bail!("truncated threshold table at offset {at}");
            }
            let s = &data[*at..*at + n];
            *at += n;
            Ok(s)
        };
        let rd_f32 = |at: &mut usize| -> Result<f32> {
            Ok(f32::from_le_bytes(take(at, 4)?.try_into().unwrap()))
        };
        let dense_hi = rd_f32(&mut at)?;
        let sparse_lo = rd_f32(&mut at)?;
        let n = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
        let mut entries = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let layer_id = u16::from_le_bytes(take(&mut at, 2)?.try_into().unwrap());
            let provisional = KernelMode::from_u8(take(&mut at, 1)?[0])?;
            let feat_density = rd_f32(&mut at)?;
            let adj_density = rd_f32(&mut at)?;
            entries.push(ThresholdEntry { layer_id, provisional, feat_density, adj_density });
        }
        Ok((ThresholdTable { dense_hi, sparse_lo, entries }, at))
    }
}

/// SpDMM effective-cycle derate assumed by the analytic break-even:
/// shuffle-network conflicts plus RAW-unit stalls (paper Sec. 5.4–5.5)
/// roughly double the ideal edge-stream trip count on skewed tiles.
const SPDMM_DERATE: f32 = 2.0;

/// Tile density at which a dense GEMM of an adjacency subshard costs the
/// same modeled cycles as streaming its edges through SpDMM.
///
/// Both modes sustain `p_sys^2`-scale MACs per cycle (Alg. 1–2), but the
/// edge stream moves `2·ne` index/value pairs where the dense tile moves
/// `rows·cols` elements, so SpDMM work scales with `2·d` and the ratio
/// is independent of `p_sys`: break-even at `d = 1 / (2·derate)`.
pub fn break_even_density() -> f32 {
    1.0 / (2.0 * SPDMM_DERATE)
}

/// Exact density of one adjacency subshard: edges over tile area.
pub fn tile_density(ne: u64, rows: u64, cols: u64) -> f32 {
    ne as f32 / (rows * cols).max(1) as f32
}

/// Exact mean density over the *non-empty* subshards of the adjacency —
/// the quantity whose divergence from the whole-graph average motivates
/// per-tile decisions (empty tiles are skipped at compile time already).
/// One scan shared with the streaming tracker: this is
/// [`DensityTracker::from_tiles`] read out once.
pub fn adjacency_density(tiles: &TileCounts, nv: u64) -> f32 {
    DensityTracker::from_tiles(tiles, nv).density()
}

/// Incrementally maintained adjacency density — the streaming
/// counterpart of [`adjacency_density`].
///
/// A full re-profile scans every subshard (O(shards²)); under edge
/// churn only the *dirty* subshards change, so
/// [`crate::stream::DynamicGraph`] keeps one of these and calls
/// [`DensityTracker::retile`] per dirty tile after an update batch.
/// The tracked value is exactly the mean density over non-empty
/// subshards (empty tiles contribute no area — they are skipped at
/// compile time already), so the GA02 threshold table a later
/// epoch-compile embeds sees the same number a from-scratch profile
/// would produce.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DensityTracker {
    /// Total edges over non-empty subshards.
    pub edges: u64,
    /// Total cell area (rows × cols) over non-empty subshards.
    pub area: u64,
}

impl DensityTracker {
    /// Full profile — same loop as [`adjacency_density`], kept as the
    /// re-sync path (vertex growth changes many tile areas at once).
    pub fn from_tiles(tiles: &TileCounts, nv: u64) -> DensityTracker {
        let n1 = tiles.n1;
        let shards = tiles.shards;
        let mut t = DensityTracker::default();
        for i in 0..shards {
            let rows = (nv - (i as u64) * n1).min(n1);
            for j in 0..shards {
                let ne = tiles.get(i, j);
                if ne == 0 {
                    continue;
                }
                let cols = (nv - (j as u64) * n1).min(n1);
                t.edges += ne;
                t.area += rows * cols;
            }
        }
        t
    }

    /// Re-profile one subshard that changed from `(old_ne, old_cells)`
    /// to `(new_ne, new_cells)` edges/area. Tiles contribute area only
    /// while non-empty, matching [`adjacency_density`].
    pub fn retile(&mut self, old_ne: u64, old_cells: u64, new_ne: u64, new_cells: u64) {
        if old_ne > 0 {
            self.edges -= old_ne;
            self.area -= old_cells;
        }
        if new_ne > 0 {
            self.edges += new_ne;
            self.area += new_cells;
        }
    }

    /// Mean density over non-empty subshards (0 when the graph has no
    /// edges).
    pub fn density(&self) -> f32 {
        self.edges as f32 / self.area.max(1) as f32
    }
}

/// Cheap analytic estimator of each layer's *input* feature-matrix
/// density (index-aligned with `ir.layers`). No feature values are
/// inspected — the chain is closed-form over the layer DAG:
///
/// * graph input features: dense (1.0);
/// * Linear output: dense (a GEMM fills every element);
/// * Aggregate output: a row is nonzero when any in-neighbor row is —
///   `1 - (1 - d_in)^mean_degree`;
/// * VectorAdd: union of the two parents' supports;
/// * ReLU (fused or standalone): halves density (symmetric inputs);
/// * VectorInner / BatchNorm: features pass through.
pub fn feature_density_estimates(ir: &ModelIr) -> Vec<f32> {
    use std::collections::HashMap;
    let mut out_d: HashMap<u16, f32> = HashMap::new();
    let mut inputs = Vec::with_capacity(ir.layers.len());
    for layer in &ir.layers {
        let d_in = layer
            .parents
            .first()
            .and_then(|p| out_d.get(p).copied())
            .unwrap_or(1.0);
        inputs.push(d_in);
        let mean_deg = (layer.ne as f32 / layer.nv.max(1) as f32).max(1.0);
        let mut d_out = match layer.ltype {
            LayerType::Linear => 1.0,
            LayerType::Aggregate => 1.0 - (1.0 - d_in).powf(mean_deg),
            LayerType::VectorAdd => {
                let d2 = layer
                    .parents
                    .get(1)
                    .and_then(|p| out_d.get(p).copied())
                    .unwrap_or(d_in);
                (d_in + d2).min(1.0)
            }
            LayerType::VectorInner | LayerType::Activation | LayerType::BatchNorm => d_in,
        };
        let relu = layer.act == Activation::Relu
            && (layer.act_enabled || layer.ltype == LayerType::Activation);
        if relu {
            d_out *= 0.5;
        }
        out_d.insert(layer.id, d_out.clamp(0.0, 1.0));
    }
    inputs
}

/// Build the threshold table the compiler embeds in the `.ga` binary:
/// the hysteresis band sits below the analytic break-even (so the
/// runtime evaluates candidates the cycle model then accepts or
/// rejects), and each layer records its provisional mode plus the
/// densities that justified it.
pub fn build_table(ir: &ModelIr, tiles: &TileCounts) -> ThresholdTable {
    let dense_hi = break_even_density() * 0.5;
    let sparse_lo = dense_hi * 0.5;
    let feats = feature_density_estimates(ir);
    let adj = adjacency_density(tiles, ir.graph.n_vertices);
    let entries = ir
        .layers
        .iter()
        .zip(&feats)
        .map(|(l, &fd)| {
            let touches_adj =
                matches!(l.ltype, LayerType::Aggregate | LayerType::VectorInner);
            // The provisional mode is exactly what the emitted
            // instructions encode (Aggregate -> SpDMM, Linear -> GEMM,
            // ...): per-tile densities override it at run time, the
            // whole-graph average merely rides along in `adj_density`.
            let provisional = match l.ltype {
                LayerType::Aggregate => KernelMode::Spdmm,
                LayerType::Linear => KernelMode::Gemm,
                LayerType::VectorInner => KernelMode::Sddmm,
                LayerType::VectorAdd
                | LayerType::Activation
                | LayerType::BatchNorm => KernelMode::Eltwise,
            };
            ThresholdEntry {
                layer_id: l.id,
                provisional,
                feat_density: fd,
                adj_density: if touches_adj { adj } else { 0.0 },
            }
        })
        .collect();
    ThresholdTable { dense_hi, sparse_lo, entries }
}

/// The per-Tiling-Block runtime decision: override the provisional mode
/// when the measured density leaves the hysteresis band. Only the
/// GEMM<->SpDMM pair re-maps (they compute the same weighted sum two
/// ways); SDDMM and the element-wise path have no cheaper alternative.
pub fn choose_mode(provisional: KernelMode, density: f32, tt: &ThresholdTable) -> KernelMode {
    match provisional {
        KernelMode::Spdmm if density >= tt.dense_hi => KernelMode::Gemm,
        KernelMode::Gemm if density <= tt.sparse_lo => KernelMode::Spdmm,
        m => m,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;
    use crate::ir::ZooModel;

    #[test]
    fn kernel_mode_wire_roundtrip() {
        for m in [KernelMode::Gemm, KernelMode::Spdmm, KernelMode::Sddmm, KernelMode::Eltwise] {
            assert_eq!(KernelMode::from_u8(m.as_u8()).unwrap(), m);
        }
        assert!(KernelMode::from_u8(9).is_err());
    }

    #[test]
    fn table_roundtrips_and_sizes() {
        let tt = ThresholdTable {
            dense_hi: 0.125,
            sparse_lo: 0.0625,
            entries: vec![
                ThresholdEntry {
                    layer_id: 1,
                    provisional: KernelMode::Spdmm,
                    feat_density: 1.0,
                    adj_density: 0.002,
                },
                ThresholdEntry {
                    layer_id: 2,
                    provisional: KernelMode::Gemm,
                    feat_density: 0.5,
                    adj_density: 0.0,
                },
            ],
        };
        let bytes = tt.to_bytes();
        assert_eq!(bytes.len() as u64, tt.size_bytes());
        let (back, used) = ThresholdTable::from_bytes(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, tt);
        // Truncations are rejected, never panic.
        for cut in 0..bytes.len() {
            assert!(ThresholdTable::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn densities_are_sane() {
        let ds = dataset("CO").unwrap();
        let tiles = ds.tile_counts(16384);
        let d = adjacency_density(&tiles, ds.n_vertices);
        // Cora-scale graphs are far below the dense band.
        assert!(d > 0.0 && d < 0.05, "CO density {d}");
        assert_eq!(tile_density(50, 10, 10), 0.5);
        assert_eq!(tile_density(0, 0, 0), 0.0);
    }

    #[test]
    fn density_tracker_matches_full_profile() {
        let ds = dataset("PU").unwrap();
        let nv = ds.n_vertices;
        let mut tiles = ds.tile_counts(16384);
        let mut t = DensityTracker::from_tiles(&tiles, nv);
        assert_eq!(t.density(), adjacency_density(&tiles, nv));
        // Mutate a few tiles and re-profile only them: the tracker must
        // agree with a from-scratch scan after every step.
        let shards = tiles.shards;
        let n1 = tiles.n1;
        let cells = |i: usize, j: usize| {
            (nv - i as u64 * n1).min(n1) * (nv - j as u64 * n1).min(n1)
        };
        for (i, j, new_ne) in [(0usize, 0usize, 123u64), (0, 1, 0), (1, 1, 1)] {
            let old = tiles.get(i, j);
            tiles.counts[i * shards + j] = new_ne;
            t.retile(old, cells(i, j), new_ne, cells(i, j));
            assert_eq!(
                t.density(),
                adjacency_density(&tiles, nv),
                "tile ({i},{j}) -> {new_ne}"
            );
        }
    }

    #[test]
    fn feature_estimator_tracks_the_dag() {
        let ds = dataset("CO").unwrap();
        let ir = ZooModel::B1.build(ds.meta());
        let est = feature_density_estimates(&ir);
        assert_eq!(est.len(), ir.layers.len());
        // The graph input is dense; every estimate is a probability.
        assert_eq!(est[0], 1.0);
        assert!(est.iter().all(|d| (0.0..=1.0).contains(d)));
    }

    #[test]
    fn hysteresis_band_drives_choose_mode() {
        let ds = dataset("CO").unwrap();
        let tiles = ds.tile_counts(16384);
        let ir = ZooModel::B1.build(ds.meta());
        let tt = build_table(&ir, &tiles);
        assert!(0.0 < tt.sparse_lo && tt.sparse_lo < tt.dense_hi);
        assert!(tt.dense_hi < break_even_density());
        assert_eq!(tt.entries.len(), ir.layers.len());
        // Inside the band the provisional choice stands; outside it flips.
        let mid = (tt.sparse_lo + tt.dense_hi) * 0.5;
        assert_eq!(choose_mode(KernelMode::Spdmm, mid, &tt), KernelMode::Spdmm);
        assert_eq!(choose_mode(KernelMode::Gemm, mid, &tt), KernelMode::Gemm);
        assert_eq!(choose_mode(KernelMode::Spdmm, tt.dense_hi, &tt), KernelMode::Gemm);
        assert_eq!(choose_mode(KernelMode::Gemm, tt.sparse_lo, &tt), KernelMode::Spdmm);
        // SDDMM / element-wise never re-map.
        assert_eq!(choose_mode(KernelMode::Sddmm, 1.0, &tt), KernelMode::Sddmm);
        assert_eq!(choose_mode(KernelMode::Eltwise, 0.0, &tt), KernelMode::Eltwise);
    }
}
