//! Minimal JSON value, writer, and parser — the offline vendor set has
//! no serde, and the daemon's trace format + wire protocol need a real
//! (escaping, round-tripping) codec rather than ad-hoc `format!` calls.
//!
//! Design points that matter to the recordable-trace guarantee:
//!
//! * **f64 round-trips bit-exactly.** Values are written with Rust's
//!   shortest-representation `Display` and re-parsed with
//!   `str::parse::<f64>`, which the standard library guarantees to be
//!   an exact inverse for finite values — so virtual-clock latencies
//!   survive a record → replay → verify cycle without drift.
//! * **Objects preserve insertion order** (a `Vec` of pairs, not a
//!   map), so encoding is deterministic and trace files diff cleanly.
//! * **Unknown fields are ignored by lookup**, which is the trace
//!   format's forward-compatibility rule: a newer writer may append
//!   fields, an older reader only consults the keys it knows.

use anyhow::{bail, Result};
use std::fmt;

/// One JSON value. Numbers are `f64` (integer counters in traces stay
/// far below 2^53, where f64 is exact).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from key/value pairs (insertion order kept).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Member lookup on an object; `None` for missing keys or
    /// non-objects. Unknown sibling keys are simply never consulted —
    /// the forward-compat rule.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-member accessors: one line per field at decode sites,
    /// with the missing/mistyped key named in the error.
    pub fn f64_of(&self, key: &str) -> Result<f64> {
        match self.get(key).and_then(Json::as_f64) {
            Some(v) => Ok(v),
            None => bail!("missing or non-numeric field '{key}'"),
        }
    }

    pub fn u64_of(&self, key: &str) -> Result<u64> {
        let v = self.f64_of(key)?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("field '{key}' is not a non-negative integer ({v})");
        }
        Ok(v as u64)
    }

    pub fn u32_of(&self, key: &str) -> Result<u32> {
        let v = self.u64_of(key)?;
        if v > u32::MAX as u64 {
            bail!("field '{key}' exceeds u32 ({v})");
        }
        Ok(v as u32)
    }

    pub fn bool_of(&self, key: &str) -> Result<bool> {
        match self.get(key).and_then(Json::as_bool) {
            Some(v) => Ok(v),
            None => bail!("missing or non-boolean field '{key}'"),
        }
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        match self.get(key).and_then(Json::as_str) {
            Some(v) => Ok(v),
            None => bail!("missing or non-string field '{key}'"),
        }
    }

    pub fn arr_of(&self, key: &str) -> Result<&[Json]> {
        match self.get(key).and_then(Json::as_arr) {
            Some(v) => Ok(v),
            None => bail!("missing or non-array field '{key}'"),
        }
    }

    /// Parse a JSON document (the whole string must be one value).
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {} of JSON document", p.pos);
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact canonical encoding (no whitespace, insertion-ordered
    /// keys, shortest-round-trip numbers).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            // Rust's Display for finite f64 is the shortest string that
            // parses back to the same bits; non-finite values never
            // occur in traces (virtual-clock arithmetic is finite).
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(pairs) => {
                write!(f, "{{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Nesting ceiling: traces are a few levels deep; a hostile frame
/// cannot stack-overflow the daemon.
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("JSON nesting exceeds {MAX_DEPTH}");
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(b) => bail!("unexpected byte '{}' at {}", b as char, self.pos),
            None => bail!("unexpected end of JSON document"),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: run of plain bytes (valid UTF-8 by
            // construction — the document is a &str).
            while let Some(b) = self.peek() {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek();
                    self.pos += 1;
                    match esc {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pairs: a high surrogate must be
                            // followed by \uDC00..DFFF.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() != Some(b'\\') {
                                    bail!("unpaired surrogate at byte {}", self.pos);
                                }
                                self.pos += 1;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate at byte {}", self.pos);
                                }
                                let v = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(v)
                            } else {
                                char::from_u32(cp)
                            };
                            match c {
                                Some(c) => out.push(c),
                                None => bail!("invalid \\u escape at byte {}", self.pos),
                            }
                        }
                        _ => bail!("invalid escape at byte {}", self.pos),
                    }
                }
                Some(b) if b < 0x20 => bail!("raw control byte in string at {}", self.pos),
                _ => bail!("unterminated string at byte {}", self.pos),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape at byte {}", self.pos);
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {}", self.pos))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {}", self.pos))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => bail!("invalid number '{s}' at byte {start}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_basic_values() {
        let v = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Str("x\"y\\z\n".into())),
            ("c", Json::Arr(vec![Json::Bool(true), Json::Null, Json::Num(-2.5)])),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn f64_round_trip_is_bit_exact() {
        // The property the trace format leans on: shortest-Display +
        // parse is the identity on finite f64 bits.
        for &x in &[0.0, 1e-9, 1.0 / 3.0, 123456.789e-4, 5.4321e17, f64::MIN_POSITIVE] {
            let s = Json::Num(x).to_string();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} -> {s} -> {back}");
        }
    }

    #[test]
    fn unknown_fields_are_ignored_by_lookup() {
        let v = Json::parse(r#"{"known": 1, "from_the_future": {"deep": [1,2]}}"#).unwrap();
        assert_eq!(v.f64_of("known").unwrap(), 1.0);
        assert!(v.get("absent").is_none());
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated", "{\"a\":1}x",
            "nul", "[1 2]", "\"bad \\q escape\"", "\"\\ud800 unpaired\"",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn depth_limit_stops_hostile_nesting() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(30) + &"]".repeat(30);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""aé😀b""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aé😀b");
        // Control characters escape on output and round-trip.
        let s = Json::Str("\u{1}\u{2}".into()).to_string();
        assert_eq!(s, "\"\\u0001\\u0002\"");
        assert_eq!(Json::parse(&s).unwrap().as_str().unwrap(), "\u{1}\u{2}");
    }

    #[test]
    fn typed_accessors_name_the_field() {
        let v = Json::parse(r#"{"n": 1.5, "i": 3, "s": "x", "b": true, "a": []}"#).unwrap();
        assert_eq!(v.u64_of("i").unwrap(), 3);
        assert_eq!(v.str_of("s").unwrap(), "x");
        assert!(v.bool_of("b").unwrap());
        assert!(v.arr_of("a").unwrap().is_empty());
        let err = v.u64_of("n").unwrap_err().to_string();
        assert!(err.contains("'n'"), "{err}");
        let err = v.f64_of("missing").unwrap_err().to_string();
        assert!(err.contains("'missing'"), "{err}");
    }
}
