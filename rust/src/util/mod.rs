//! Small shared utilities: deterministic PRNG, timers, formatting, and a
//! minimal property-testing harness (the offline vendor set has no
//! proptest; `forall` gives us seeded randomized invariants with failure
//! reporting).

pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use prop::forall;
pub use rng::Rng;

use std::time::Instant;

/// Measure wall-clock time of `f`, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Human formatting for latencies expressed in milliseconds.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2} s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{ms:.3} ms")
    } else {
        format!("{:.1} us", ms * 1000.0)
    }
}

/// Human formatting for byte sizes.
pub fn fmt_bytes(b: u64) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GB", b / KB / KB / KB)
    } else if b >= KB * KB {
        format!("{:.3} MB", b / KB / KB)
    } else if b >= KB {
        format!("{:.1} KB", b / KB)
    } else {
        format!("{b} B")
    }
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ms(1500.0), "1.50 s");
        assert_eq!(fmt_ms(2.5), "2.500 ms");
        assert_eq!(fmt_ms(0.5), "500.0 us");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KB");
    }

    #[test]
    fn timed_returns_result() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
