//! Minimal property-testing harness (no proptest in the offline vendor
//! set). `forall` runs a seeded closure N times with independent RNGs and
//! reports the failing seed so a failure reproduces exactly.

use super::rng::Rng;

/// Run `body` for `cases` seeded RNGs. On panic-free falsification
/// (`body` returns `Err(msg)`), panic with the reproducing seed.
pub fn forall(name: &str, cases: u64, mut body: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        // Decorrelate case seeds; keep them printable/reproducible.
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = body(&mut rng) {
            panic!("property `{name}` falsified at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Convenience assertion for use inside `forall` bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64-below", 50, |rng| {
            let n = rng.range(1, 1000);
            let x = rng.below(n);
            if x < n { Ok(()) } else { Err(format!("{x} >= {n}")) }
        });
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn forall_reports_failures() {
        forall("always-false", 3, |_| Err("nope".into()));
    }
}
