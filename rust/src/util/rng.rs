//! Deterministic xoshiro256** PRNG — reproducible synthetic graphs and
//! property tests without external crates.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses the widening-multiply trick (unbiased
    /// enough for simulation workloads; exact rejection not required).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
