//! High-level instruction definitions (paper Sec. 5.3.1, Fig. 3).
//!
//! All high-level instructions are 128 bits with an 8-bit OPCODE field;
//! the remaining fields are instruction-specific. A Tiling Block is an
//! inseparable sequence of these, executed by one PE; the Scheduler only
//! ever interprets the Control-and-Scheduling Instruction (CSI) that heads
//! a Layer Block.

/// Instruction opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    /// Control & Scheduling: layer meta data for the Scheduler.
    Csi = 0,
    /// DDR -> on-chip buffer read.
    MemRead = 1,
    /// On-chip buffer -> DDR write.
    MemWrite = 2,
    /// Dense matmul on the ACK systolic datapath.
    Gemm = 3,
    /// Edge-centric sparse-dense matmul (scatter-gather).
    Spdmm = 4,
    /// Edge-centric sampled dense-dense matmul (adder trees).
    Sddmm = 5,
    /// Vector addition (residuals).
    Vadd = 6,
    /// Standalone element-wise activation (when not fused).
    Act = 7,
    /// Initialize an output accumulator tile.
    Init = 8,
    /// End of program.
    Halt = 9,
}

impl Opcode {
    pub fn from_u8(v: u8) -> Option<Opcode> {
        use Opcode::*;
        Some(match v {
            0 => Csi,
            1 => MemRead,
            2 => MemWrite,
            3 => Gemm,
            4 => Spdmm,
            5 => Sddmm,
            6 => Vadd,
            7 => Act,
            8 => Init,
            9 => Halt,
            _ => return None,
        })
    }
}

/// Element-wise aggregation operators (Table 2). Mean is realized as Sum
/// with pre-normalized edge weights, keeping the operator linear.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum AggOp {
    Sum = 0,
    Max = 1,
    Min = 2,
    Mean = 3,
}

impl AggOp {
    pub fn from_u8(v: u8) -> Option<AggOp> {
        Some(match v {
            0 => AggOp::Sum,
            1 => AggOp::Max,
            2 => AggOp::Min,
            3 => AggOp::Mean,
            _ => return None,
        })
    }

    /// Linearity (Definition 1): Sum/Mean distribute over the Linear
    /// layer's matmul; Max/Min do not.
    pub fn is_linear(&self) -> bool {
        matches!(self, AggOp::Sum | AggOp::Mean)
    }
}

/// Activation functions supported by the Activation Unit (Sec. 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Activation {
    None = 0,
    Relu = 1,
    PRelu = 2,
    LRelu = 3,
    Swish = 4,
    Exp = 5,
    Sigmoid = 6,
    Elu = 7,
}

impl Activation {
    pub fn from_u8(v: u8) -> Option<Activation> {
        use Activation::*;
        Some(match v {
            0 => None,
            1 => Relu,
            2 => PRelu,
            3 => LRelu,
            4 => Swish,
            5 => Exp,
            6 => Sigmoid,
            7 => Elu,
            _ => return Option::None,
        })
    }
}

/// On-chip buffer identifiers. Feature buffers are triple-buffered and
/// Edge/Weight double-buffered (Sec. 7); the mutex bit in memory
/// instructions protects against WAR hazards between the decoder's
/// look-ahead issue and in-flight compute (Sec. 6.6).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum BufferId {
    Edge0 = 0,
    Edge1 = 1,
    Weight0 = 2,
    Weight1 = 3,
    Feature0 = 4,
    Feature1 = 5,
    Feature2 = 6,
    /// Result staging region of the Feature Buffer.
    Result = 7,
}

impl BufferId {
    pub fn from_u8(v: u8) -> Option<BufferId> {
        use BufferId::*;
        Some(match v {
            0 => Edge0,
            1 => Edge1,
            2 => Weight0,
            3 => Weight1,
            4 => Feature0,
            5 => Feature1,
            6 => Feature2,
            7 => Result,
            _ => return None,
        })
    }

    pub fn is_edge(&self) -> bool {
        matches!(self, BufferId::Edge0 | BufferId::Edge1)
    }
}

/// A decoded high-level instruction. Field widths are chosen to pack into
/// 128 bits (see `encode`); the encoder asserts the ranges.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Instr {
    /// Layer Block header: everything the Scheduler needs to fan Tiling
    /// Blocks out to idle PEs (Alg. 9).
    Csi {
        layer_id: u16,
        layer_type: u8,
        n_tiling_blocks: u32,
    },
    /// Load `bytes` from DDR address `addr` into `buf`. `lock` marks the
    /// buffer mutex acquired until the consuming compute retires (WAR).
    MemRead {
        buf: BufferId,
        addr: u64,
        bytes: u32,
        lock: bool,
    },
    /// Store `bytes` to DDR from `buf`.
    MemWrite {
        buf: BufferId,
        addr: u64,
        bytes: u32,
    },
    /// Block matmul H_B (rows x len) x W_B (len x cols); `accumulate`
    /// keeps the systolic output stationary across len-chunks.
    Gemm {
        rows: u32,
        len: u16,
        cols: u16,
        act: Activation,
        accumulate: bool,
    },
    /// Edge-centric SpDMM over `n_edges` of a subshard at feature width
    /// `feat` (paper: the edge count enables edge-centric execution).
    Spdmm {
        n_edges: u32,
        feat: u16,
        aggop: AggOp,
        act: Activation,
    },
    /// Edge-centric SDDMM over `n_edges` with vectors of length `feat`.
    Sddmm {
        n_edges: u32,
        feat: u16,
        act: Activation,
    },
    /// Vector addition over a rows x cols tile.
    Vadd {
        rows: u32,
        cols: u16,
        act: Activation,
    },
    /// Standalone activation over a rows x cols tile (only emitted when
    /// fusion is disabled — Fig. 15 ablation).
    Act {
        rows: u32,
        cols: u16,
        act: Activation,
    },
    /// Zero/neutral-initialize an accumulator tile of rows x cols.
    Init {
        rows: u32,
        cols: u16,
        aggop: AggOp,
    },
    Halt,
}

impl Instr {
    pub fn opcode(&self) -> Opcode {
        match self {
            Instr::Csi { .. } => Opcode::Csi,
            Instr::MemRead { .. } => Opcode::MemRead,
            Instr::MemWrite { .. } => Opcode::MemWrite,
            Instr::Gemm { .. } => Opcode::Gemm,
            Instr::Spdmm { .. } => Opcode::Spdmm,
            Instr::Sddmm { .. } => Opcode::Sddmm,
            Instr::Vadd { .. } => Opcode::Vadd,
            Instr::Act { .. } => Opcode::Act,
            Instr::Init { .. } => Opcode::Init,
            Instr::Halt => Opcode::Halt,
        }
    }

    /// True for instructions executed by the ACK datapath (vs. memory /
    /// control instructions).
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            Instr::Gemm { .. }
                | Instr::Spdmm { .. }
                | Instr::Sddmm { .. }
                | Instr::Vadd { .. }
                | Instr::Act { .. }
                | Instr::Init { .. }
        )
    }

    /// Bytes moved by memory instructions (0 otherwise).
    pub fn mem_bytes(&self) -> u64 {
        match self {
            Instr::MemRead { bytes, .. } | Instr::MemWrite { bytes, .. } => *bytes as u64,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_roundtrip() {
        for v in 0..=9u8 {
            let op = Opcode::from_u8(v).unwrap();
            assert_eq!(op as u8, v);
        }
        assert!(Opcode::from_u8(10).is_none());
    }

    #[test]
    fn aggop_linearity() {
        assert!(AggOp::Sum.is_linear());
        assert!(AggOp::Mean.is_linear());
        assert!(!AggOp::Max.is_linear());
        assert!(!AggOp::Min.is_linear());
    }

    #[test]
    fn instr_classification() {
        let g = Instr::Gemm {
            rows: 128,
            len: 64,
            cols: 16,
            act: Activation::Relu,
            accumulate: false,
        };
        assert!(g.is_compute());
        assert_eq!(g.opcode(), Opcode::Gemm);
        let m = Instr::MemRead {
            buf: BufferId::Edge0,
            addr: 0x1000,
            bytes: 4096,
            lock: true,
        };
        assert!(!m.is_compute());
        assert_eq!(m.mem_bytes(), 4096);
    }

    #[test]
    fn buffer_id_roundtrip() {
        for v in 0..=7u8 {
            assert_eq!(BufferId::from_u8(v).unwrap() as u8, v);
        }
        assert!(BufferId::from_u8(8).is_none());
        assert!(BufferId::Edge1.is_edge());
        assert!(!BufferId::Result.is_edge());
    }
}
