//! The GraphAGILE instruction set architecture (paper Sec. 5.3).
//!
//! * [`instr`] — the 128-bit high-level instructions (Fig. 3),
//! * [`encode`] — bit-exact encode/decode to the 16-byte wire format,
//! * [`microcode`] — expansion of high-level instructions into the
//!   fine-grained microcode the ACK executes (Alg. 1–3) plus the
//!   closed-form cycle algebra the simulator uses,
//! * [`binary`] — the `.ga` executable format produced by the compiler's
//!   code generation (Table 8 measures its size).

pub mod binary;
pub mod disasm;
pub mod encode;
pub mod instr;
pub mod microcode;

pub use binary::{LayerBlock, Program, TilingBlock};
pub use instr::{AggOp, Activation, BufferId, Instr, Opcode};
pub use microcode::{instr_cycles, MicroOp};
