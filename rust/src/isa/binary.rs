//! The `.ga` executable format (compiler output; Table 8 measures sizes).
//!
//! Layout (version 3):
//! ```text
//! magic "GA03"           4 bytes         ("GA01"/"GA02" = older layouts)
//! n1, n2                 u32 each        (partition configuration)
//! model/graph names      u16 len + utf8 each
//! threshold section      u8 flag + ThresholdTable body (GA02+)
//! scale section          u8 flag + ScaleTable body (GA03 only)
//! n_layer_blocks         u32
//! per Layer Block:
//!   CSI instruction      16 bytes
//!   n_tiling_blocks      u32
//!   per Tiling Block:
//!     n_instrs           u32
//!     instructions       16 bytes each
//! HALT                   16 bytes
//! ```
//!
//! Version history: `GA01` is the original format; `GA02` inserts the
//! optional density-threshold section (`crate::sparsity::ThresholdTable`)
//! between the names and the Layer Blocks; `GA03` appends the optional
//! int8 calibration section (`crate::quant::ScaleTable`) after it. The
//! writer always emits the **oldest sufficient** magic: no scales and no
//! thresholds serializes byte-identically to a legacy `GA01` binary, and
//! thresholds-only to a `GA02` one (under `GA03` the threshold flag byte
//! is always present, 0 or 1, so the scale flag has a fixed anchor). The
//! reader accepts all three magics — old binaries keep loading, new
//! readers see `thresholds: None` / `scales: None` for them.
//!
//! The Scheduler streams this from DDR: only the CSI of the current layer
//! is resident on-chip; Tiling Blocks are forwarded whole to PE
//! instruction queues (Sec. 4.2).

use super::encode::{decode, encode, INSTR_BYTES};
use super::instr::Instr;
use crate::quant::ScaleTable;
use crate::sparsity::ThresholdTable;
use anyhow::{bail, Context, Result};

/// An inseparable instruction sequence executed by one PE (Sec. 6.6).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct TilingBlock {
    pub instrs: Vec<Instr>,
}

impl TilingBlock {
    pub fn new(instrs: Vec<Instr>) -> Self {
        TilingBlock { instrs }
    }

    /// ACK-busy cycles of this block at width `p_sys`.
    pub fn compute_cycles(&self, p_sys: usize) -> u64 {
        self.instrs.iter().map(|i| super::microcode::instr_cycles(i, p_sys)).sum()
    }

    /// Bytes read from DDR by this block.
    pub fn read_bytes(&self) -> u64 {
        self.instrs
            .iter()
            .filter(|i| matches!(i, Instr::MemRead { .. }))
            .map(|i| i.mem_bytes())
            .sum()
    }

    /// Bytes written to DDR by this block.
    pub fn write_bytes(&self) -> u64 {
        self.instrs
            .iter()
            .filter(|i| matches!(i, Instr::MemWrite { .. }))
            .map(|i| i.mem_bytes())
            .sum()
    }
}

/// One computation layer: a CSI header plus its Tiling Blocks (Sec. 6.6,
/// "Kernel Mapping").
#[derive(Clone, Debug, PartialEq)]
pub struct LayerBlock {
    pub csi: Instr,
    pub blocks: Vec<TilingBlock>,
}

/// A complete executable.
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    pub n1: u32,
    pub n2: u32,
    pub model_name: String,
    pub graph_name: String,
    /// Optional density-threshold table for runtime kernel re-mapping
    /// (the GA02 section; `None` round-trips as a legacy GA01 binary).
    pub thresholds: Option<ThresholdTable>,
    /// Optional int8 calibration table (the GA03 section). A program
    /// carrying scales executes its eligible subshards on the quantized
    /// datapath; `None` round-trips as a GA01/GA02 binary.
    pub scales: Option<ScaleTable>,
    pub layers: Vec<LayerBlock>,
}

const MAGIC_V1: &[u8; 4] = b"GA01";
const MAGIC_V2: &[u8; 4] = b"GA02";
const MAGIC_V3: &[u8; 4] = b"GA03";

impl Program {
    /// Serialize to the wire format. Emits the oldest sufficient magic:
    /// `GA01` with neither optional section, `GA02` with thresholds
    /// only, `GA03` whenever a scale table is attached.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.size_bytes() as usize);
        out.extend_from_slice(if self.scales.is_some() {
            MAGIC_V3
        } else if self.thresholds.is_some() {
            MAGIC_V2
        } else {
            MAGIC_V1
        });
        out.extend_from_slice(&self.n1.to_le_bytes());
        out.extend_from_slice(&self.n2.to_le_bytes());
        for name in [&self.model_name, &self.graph_name] {
            let b = name.as_bytes();
            out.extend_from_slice(&(b.len() as u16).to_le_bytes());
            out.extend_from_slice(b);
        }
        if let Some(tt) = &self.thresholds {
            out.push(1);
            out.extend_from_slice(&tt.to_bytes());
        } else if self.scales.is_some() {
            // GA03 always carries the threshold flag byte so the scale
            // flag sits at a fixed position after it.
            out.push(0);
        }
        if let Some(st) = &self.scales {
            out.push(1);
            out.extend_from_slice(&st.to_bytes());
        }
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for layer in &self.layers {
            out.extend_from_slice(&encode(&layer.csi));
            out.extend_from_slice(&(layer.blocks.len() as u32).to_le_bytes());
            for block in &layer.blocks {
                out.extend_from_slice(&(block.instrs.len() as u32).to_le_bytes());
                for instr in &block.instrs {
                    out.extend_from_slice(&encode(instr));
                }
            }
        }
        out.extend_from_slice(&encode(&Instr::Halt));
        out
    }

    /// Parse the wire format (errors, never panics, on corrupt input).
    pub fn from_bytes(data: &[u8]) -> Result<Program> {
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Result<&[u8]> {
            if *at + n > data.len() {
                bail!("truncated program at offset {at}");
            }
            let s = &data[*at..*at + n];
            *at += n;
            Ok(s)
        };
        let version = match take(&mut at, 4)? {
            m if m == MAGIC_V1 => 1,
            m if m == MAGIC_V2 => 2,
            m if m == MAGIC_V3 => 3,
            _ => bail!("bad magic"),
        };
        let rd_u32 = |at: &mut usize| -> Result<u32> {
            Ok(u32::from_le_bytes(take(at, 4)?.try_into().unwrap()))
        };
        let rd_u16 = |at: &mut usize| -> Result<u16> {
            Ok(u16::from_le_bytes(take(at, 2)?.try_into().unwrap()))
        };
        let rd_instr = |at: &mut usize| -> Result<Instr> {
            let b: [u8; INSTR_BYTES] = take(at, INSTR_BYTES)?.try_into().unwrap();
            decode(&b)
        };
        let n1 = rd_u32(&mut at)?;
        let n2 = rd_u32(&mut at)?;
        let rd_name = |at: &mut usize| -> Result<String> {
            let len = rd_u16(at)? as usize;
            Ok(String::from_utf8(take(at, len)?.to_vec()).context("bad utf8 name")?)
        };
        let model_name = rd_name(&mut at)?;
        let graph_name = rd_name(&mut at)?;
        let thresholds = if version >= 2 {
            match take(&mut at, 1)?[0] {
                0 => None,
                1 => {
                    let (tt, used) = ThresholdTable::from_bytes(&data[at..])?;
                    at += used;
                    Some(tt)
                }
                v => bail!("bad threshold-section flag {v}"),
            }
        } else {
            None
        };
        let scales = if version >= 3 {
            match take(&mut at, 1)?[0] {
                0 => None,
                1 => {
                    let (st, used) = ScaleTable::from_bytes(&data[at..])?;
                    at += used;
                    Some(st)
                }
                v => bail!("bad scale-section flag {v}"),
            }
        } else {
            None
        };
        let n_layers = rd_u32(&mut at)? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let csi = rd_instr(&mut at)?;
            if !matches!(csi, Instr::Csi { .. }) {
                bail!("layer block does not start with CSI");
            }
            let n_blocks = rd_u32(&mut at)? as usize;
            let mut blocks = Vec::with_capacity(n_blocks);
            for _ in 0..n_blocks {
                let n_instrs = rd_u32(&mut at)? as usize;
                let mut instrs = Vec::with_capacity(n_instrs);
                for _ in 0..n_instrs {
                    instrs.push(rd_instr(&mut at)?);
                }
                blocks.push(TilingBlock::new(instrs));
            }
            layers.push(LayerBlock { csi, blocks });
        }
        match rd_instr(&mut at)? {
            Instr::Halt => {}
            other => bail!("expected HALT, got {other:?}"),
        }
        Ok(Program { n1, n2, model_name, graph_name, thresholds, scales, layers })
    }

    /// Byte offset into [`Program::to_bytes`] output whose single-byte
    /// flip is guaranteed to trip the loader's validation: the
    /// scale-section flag of a GA03 binary, the threshold-section flag
    /// of a GA02 one, and the magic itself for GA01. The fault
    /// injector damages cached artifacts here so it can rely on
    /// [`Program::from_bytes`] rejecting the result — exercising the
    /// corrupted-artifact recovery path in situ rather than
    /// simulating it.
    pub fn corruption_offset(&self) -> usize {
        let flag_at = 4 + 4 + 4 + 2 + self.model_name.len() + 2 + self.graph_name.len();
        match (&self.thresholds, &self.scales) {
            // GA01 has no section flags: flip the magic itself.
            (None, None) => 0,
            // GA02: the threshold-section flag.
            (Some(_), None) => flag_at,
            // GA03 writes an explicit empty threshold flag first.
            (None, Some(_)) => flag_at + 1,
            // GA03 with both: the scale flag follows the threshold body.
            (Some(tt), Some(_)) => flag_at + 1 + tt.size_bytes() as usize,
        }
    }

    /// Serialized size (what Table 8 reports) without materializing.
    pub fn size_bytes(&self) -> u64 {
        let mut sz = 4 + 4 + 4; // magic + n1 + n2
        sz += 2 + self.model_name.len() as u64;
        sz += 2 + self.graph_name.len() as u64;
        if let Some(tt) = &self.thresholds {
            sz += 1 + tt.size_bytes(); // GA02 section flag + body
        } else if self.scales.is_some() {
            sz += 1; // GA03 writes the empty threshold flag explicitly
        }
        if let Some(st) = &self.scales {
            sz += 1 + st.size_bytes(); // GA03 section flag + body
        }
        sz += 4; // n_layers
        for layer in &self.layers {
            sz += INSTR_BYTES as u64 + 4;
            for block in &layer.blocks {
                sz += 4 + (block.instrs.len() * INSTR_BYTES) as u64;
            }
        }
        sz + INSTR_BYTES as u64 // HALT
    }

    pub fn total_instrs(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| {
                1 + l
                    .blocks
                    .iter()
                    .map(|b| b.instrs.len() as u64)
                    .sum::<u64>()
            })
            .sum::<u64>()
            + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::{Activation, AggOp, BufferId};

    fn sample_program() -> Program {
        Program {
            n1: 16384,
            n2: 16,
            model_name: "b1".into(),
            graph_name: "CO".into(),
            thresholds: None,
            scales: None,
            layers: vec![LayerBlock {
                csi: Instr::Csi { layer_id: 1, layer_type: 0, n_tiling_blocks: 2 },
                blocks: vec![
                    TilingBlock::new(vec![
                        Instr::Init { rows: 128, cols: 16, aggop: AggOp::Sum },
                        Instr::MemRead {
                            buf: BufferId::Edge0,
                            addr: 0x100,
                            bytes: 1200,
                            lock: true,
                        },
                        Instr::Spdmm {
                            n_edges: 100,
                            feat: 16,
                            aggop: AggOp::Sum,
                            act: Activation::Relu,
                        },
                        Instr::MemWrite { buf: BufferId::Result, addr: 0x2000, bytes: 8192 },
                    ]),
                    TilingBlock::new(vec![Instr::Gemm {
                        rows: 128,
                        len: 16,
                        cols: 16,
                        act: Activation::None,
                        accumulate: false,
                    }]),
                ],
            }],
        }
    }

    #[test]
    fn roundtrip() {
        let p = sample_program();
        let bytes = p.to_bytes();
        assert_eq!(bytes.len() as u64, p.size_bytes());
        assert_eq!(&bytes[..4], b"GA01", "no thresholds -> legacy wire bytes");
        let q = Program::from_bytes(&bytes).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn threshold_section_roundtrip_and_versioned_magic() {
        use crate::sparsity::{KernelMode, ThresholdEntry, ThresholdTable};
        let mut p = sample_program();
        p.thresholds = Some(ThresholdTable {
            dense_hi: 0.125,
            sparse_lo: 0.0625,
            entries: vec![ThresholdEntry {
                layer_id: 1,
                provisional: KernelMode::Spdmm,
                feat_density: 1.0,
                adj_density: 0.2,
            }],
        });
        let bytes = p.to_bytes();
        assert_eq!(&bytes[..4], b"GA02");
        assert_eq!(bytes.len() as u64, p.size_bytes());
        let q = Program::from_bytes(&bytes).unwrap();
        assert_eq!(p, q);
        // Corrupting the section flag is rejected, not silently skipped.
        let flag_at = 4 + 4 + 4 + 2 + 2 + 2 + 2; // header + "b1" + "CO"
        assert_eq!(bytes[flag_at], 1);
        let mut bad = bytes.clone();
        bad[flag_at] = 7;
        assert!(Program::from_bytes(&bad).is_err());
        // Truncating inside the section is rejected too.
        assert!(Program::from_bytes(&bytes[..flag_at + 5]).is_err());
    }

    fn sample_scales() -> ScaleTable {
        use crate::quant::ScaleEntry;
        ScaleTable {
            input_absmax: 1.0,
            bound: 0.25,
            entries: vec![ScaleEntry {
                layer_id: 1,
                w_scale: 0.01,
                x_scale: 0.02,
                y_absmax: 3.5,
            }],
        }
    }

    #[test]
    fn scale_section_roundtrip_and_versioned_magic() {
        // Scales without thresholds: GA03 with an explicit empty
        // threshold flag ahead of the scale section.
        let mut p = sample_program();
        p.scales = Some(sample_scales());
        let bytes = p.to_bytes();
        assert_eq!(&bytes[..4], b"GA03");
        assert_eq!(bytes.len() as u64, p.size_bytes());
        let q = Program::from_bytes(&bytes).unwrap();
        assert_eq!(p, q);
        let flag_at = 4 + 4 + 4 + 2 + 2 + 2 + 2; // header + "b1" + "CO"
        assert_eq!(bytes[flag_at], 0, "empty threshold flag");
        assert_eq!(bytes[flag_at + 1], 1, "scale flag");
        // Corrupting the scale flag is rejected, not silently skipped.
        let mut bad = bytes.clone();
        bad[flag_at + 1] = 9;
        assert!(Program::from_bytes(&bad).is_err());
        // Truncating inside the scale section is rejected too.
        assert!(Program::from_bytes(&bytes[..flag_at + 6]).is_err());
    }

    #[test]
    fn both_sections_coexist_under_ga03() {
        use crate::sparsity::{KernelMode, ThresholdEntry, ThresholdTable};
        let mut p = sample_program();
        p.thresholds = Some(ThresholdTable {
            dense_hi: 0.125,
            sparse_lo: 0.0625,
            entries: vec![ThresholdEntry {
                layer_id: 1,
                provisional: KernelMode::Spdmm,
                feat_density: 1.0,
                adj_density: 0.2,
            }],
        });
        p.scales = Some(sample_scales());
        let bytes = p.to_bytes();
        assert_eq!(&bytes[..4], b"GA03");
        assert_eq!(bytes.len() as u64, p.size_bytes());
        let q = Program::from_bytes(&bytes).unwrap();
        assert_eq!(p, q);
        // Dropping the scale table falls back to GA02 byte-identically.
        let mut ga02 = p.clone();
        ga02.scales = None;
        assert_eq!(&ga02.to_bytes()[..4], b"GA02");
        assert_eq!(Program::from_bytes(&ga02.to_bytes()).unwrap(), ga02);
    }

    #[test]
    fn corruption_offset_always_trips_the_loader() {
        use crate::sparsity::{KernelMode, ThresholdEntry, ThresholdTable};
        let tt = ThresholdTable {
            dense_hi: 0.125,
            sparse_lo: 0.0625,
            entries: vec![ThresholdEntry {
                layer_id: 1,
                provisional: KernelMode::Spdmm,
                feat_density: 1.0,
                adj_density: 0.2,
            }],
        };
        // One variant per wire format: GA01, GA02, GA03 without and
        // with a threshold section.
        let mut ga02 = sample_program();
        ga02.thresholds = Some(tt.clone());
        let mut ga03 = sample_program();
        ga03.scales = Some(sample_scales());
        let mut ga03_full = sample_program();
        ga03_full.thresholds = Some(tt);
        ga03_full.scales = Some(sample_scales());
        for p in [sample_program(), ga02, ga03, ga03_full] {
            let mut bytes = p.to_bytes();
            assert!(Program::from_bytes(&bytes).is_ok());
            let off = p.corruption_offset();
            bytes[off] ^= 0xFF;
            assert!(
                Program::from_bytes(&bytes).is_err(),
                "flip at {off} must be rejected ({:?})",
                &bytes[..4]
            );
        }
    }

    #[test]
    fn corrupt_magic_rejected() {
        let mut bytes = sample_program().to_bytes();
        bytes[0] = b'X';
        assert!(Program::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let bytes = sample_program().to_bytes();
        for cut in [3, 10, bytes.len() - 1] {
            assert!(
                Program::from_bytes(&bytes[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn block_accounting() {
        let p = sample_program();
        let b = &p.layers[0].blocks[0];
        assert_eq!(b.read_bytes(), 1200);
        assert_eq!(b.write_bytes(), 8192);
        assert!(b.compute_cycles(16) > 0);
        assert_eq!(p.total_instrs(), 1 + 4 + 1 + 1);
    }

    #[test]
    fn layer_without_csi_rejected() {
        // Hand-craft: replace the CSI with a GEMM.
        let mut p = sample_program();
        p.layers[0].csi = Instr::Gemm {
            rows: 1,
            len: 1,
            cols: 1,
            act: Activation::None,
            accumulate: false,
        };
        let bytes = p.to_bytes();
        assert!(Program::from_bytes(&bytes).is_err());
    }
}
