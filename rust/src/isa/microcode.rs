//! Microcode expansion (paper Sec. 5.3.2, Alg. 1–3).
//!
//! The Instruction Decoder & Control Signal Generator translates each
//! high-level instruction into fine-grained microcode for the ACK. Two
//! forms are provided:
//!
//! * [`expand`] — an iterator over individual micro-ops (small instances;
//!   used by unit tests and the functional executor's trace mode);
//! * [`instr_cycles`] — the closed-form cycle algebra the simulator uses
//!   (property-tested to agree with `expand` exactly).
//!
//! Cycle model (ACK dimension p = p_sys):
//!   GEMM  (Alg. 1): ceil(S_B/p) * ceil(G_B/p) * Len        (one K-step/cycle)
//!   SpDMM (Alg. 2): ceil(2 N_e / p) * ceil(f / p)          (p/2 edges/cycle)
//!   SDDMM (Alg. 3): ceil(2 N_e / p) * ceil(f / p)          (p/2 products)
//!   VADD:           ceil(2 rows / p) * ceil(f / p)         (p/2 adds/cycle)
//!   ACT:            ceil(rows * cols / 16)                 (16 act elements)
//!   INIT:           ceil(rows / p)                         (row-wide clear)

use super::instr::Instr;
use crate::util::ceil_div;

/// One cycle's worth of ACK work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MicroOp {
    /// Feed column k of H_T:i and row k of W_T:j into the systolic array.
    GemmStep { i: u32, j: u32, k: u32 },
    /// Dispatch a batch of p/2 edges through ISN -> Feature Buffer -> DSN
    /// -> UR pipelines, over one p-wide feature chunk.
    EdgeBatch { batch: u32, chunk: u32 },
    /// One p-wide chunk of p/2 vector-add lanes.
    VaddStep { batch: u32, chunk: u32 },
    /// One batch of 16 activation elements.
    ActStep { batch: u32 },
    /// Clear one p-row group of the accumulator.
    InitStep { group: u32 },
}

/// Total ACK-busy cycles for `instr` at systolic width `p_sys` — the
/// closed form of Alg. 1–3's loop trip counts. Memory and control
/// instructions return 0 (their cost is modeled by sim::ddr).
pub fn instr_cycles(instr: &Instr, p_sys: usize) -> u64 {
    let p = p_sys as u64;
    match *instr {
        Instr::Gemm { rows, len, cols, .. } => {
            ceil_div(rows as u64, p) * ceil_div(cols as u64, p) * len as u64
        }
        Instr::Spdmm { n_edges, feat, .. } => {
            ceil_div(2 * n_edges as u64, p) * ceil_div(feat as u64, p)
        }
        Instr::Sddmm { n_edges, feat, .. } => {
            ceil_div(2 * n_edges as u64, p) * ceil_div(feat as u64, p)
        }
        Instr::Vadd { rows, cols, .. } => {
            ceil_div(2 * rows as u64, p) * ceil_div(cols as u64, p)
        }
        Instr::Act { rows, cols, .. } => ceil_div(rows as u64 * cols as u64, 16),
        Instr::Init { rows, .. } => ceil_div(rows as u64, p),
        Instr::Csi { .. } | Instr::MemRead { .. } | Instr::MemWrite { .. } | Instr::Halt => 0,
    }
}

/// Expand a high-level instruction into its microcode sequence. One
/// `MicroOp` == one ACK cycle, so `expand(i, p).count() == instr_cycles`.
pub fn expand(instr: &Instr, p_sys: usize) -> Box<dyn Iterator<Item = MicroOp>> {
    let p = p_sys as u64;
    match *instr {
        Instr::Gemm { rows, len, cols, .. } => {
            let (ti, tj) = (ceil_div(rows as u64, p), ceil_div(cols as u64, p));
            Box::new((0..ti).flat_map(move |i| {
                (0..tj).flat_map(move |j| {
                    (0..len as u64).map(move |k| MicroOp::GemmStep {
                        i: i as u32,
                        j: j as u32,
                        k: k as u32,
                    })
                })
            }))
        }
        Instr::Spdmm { n_edges, feat, .. } | Instr::Sddmm { n_edges, feat, .. } => {
            let batches = ceil_div(2 * n_edges as u64, p);
            let chunks = ceil_div(feat as u64, p);
            Box::new((0..batches).flat_map(move |b| {
                (0..chunks).map(move |c| MicroOp::EdgeBatch {
                    batch: b as u32,
                    chunk: c as u32,
                })
            }))
        }
        Instr::Vadd { rows, cols, .. } => {
            let batches = ceil_div(2 * rows as u64, p);
            let chunks = ceil_div(cols as u64, p);
            Box::new((0..batches).flat_map(move |b| {
                (0..chunks).map(move |c| MicroOp::VaddStep {
                    batch: b as u32,
                    chunk: c as u32,
                })
            }))
        }
        Instr::Act { rows, cols, .. } => {
            let batches = ceil_div(rows as u64 * cols as u64, 16);
            Box::new((0..batches).map(|b| MicroOp::ActStep { batch: b as u32 }))
        }
        Instr::Init { rows, .. } => {
            let groups = ceil_div(rows as u64, p);
            Box::new((0..groups).map(|g| MicroOp::InitStep { group: g as u32 }))
        }
        _ => Box::new(std::iter::empty()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::{AggOp, Activation};
    use crate::util::forall;

    #[test]
    fn gemm_cycles_match_alg1() {
        // S_B=128, Len=64, G_B=16 at p=16: (128/16)*(16/16)*64 = 512.
        let g = Instr::Gemm {
            rows: 128,
            len: 64,
            cols: 16,
            act: Activation::None,
            accumulate: false,
        };
        assert_eq!(instr_cycles(&g, 16), 512);
    }

    #[test]
    fn spdmm_cycles_match_alg2() {
        // N_e=1000 at p=16: 2*1000/16 = 125 batches; f=16 -> 1 chunk.
        let s = Instr::Spdmm {
            n_edges: 1000,
            feat: 16,
            aggop: AggOp::Sum,
            act: Activation::None,
        };
        assert_eq!(instr_cycles(&s, 16), 125);
        // f=64 -> 4 chunks per batch.
        let s2 = Instr::Spdmm {
            n_edges: 1000,
            feat: 64,
            aggop: AggOp::Sum,
            act: Activation::None,
        };
        assert_eq!(instr_cycles(&s2, 16), 500);
    }

    #[test]
    fn sddmm_paper_example() {
        // p_sys/2 inner products of length p_sys per cycle; |h| = 64 takes
        // ceil(64/16) = 4 cycles per batch of 8 edges.
        let s = Instr::Sddmm {
            n_edges: 8,
            feat: 64,
            act: Activation::None,
        };
        assert_eq!(instr_cycles(&s, 16), 4);
    }

    #[test]
    fn memory_and_control_are_free_here() {
        use crate::isa::instr::BufferId;
        assert_eq!(
            instr_cycles(
                &Instr::MemRead {
                    buf: BufferId::Edge0,
                    addr: 0,
                    bytes: 1 << 20,
                    lock: false
                },
                16
            ),
            0
        );
        assert_eq!(instr_cycles(&Instr::Halt, 16), 0);
    }

    #[test]
    fn prop_expand_count_equals_cycles() {
        forall("microcode-count", 60, |rng| {
            let act = Activation::None;
            let instr = match rng.below(6) {
                0 => Instr::Gemm {
                    rows: rng.range(1, 200) as u32,
                    len: rng.range(1, 100) as u16,
                    cols: rng.range(1, 70) as u16,
                    act,
                    accumulate: false,
                },
                1 => Instr::Spdmm {
                    n_edges: rng.range(0, 3000) as u32,
                    feat: rng.range(1, 200) as u16,
                    aggop: AggOp::Sum,
                    act,
                },
                2 => Instr::Sddmm {
                    n_edges: rng.range(0, 3000) as u32,
                    feat: rng.range(1, 200) as u16,
                    act,
                },
                3 => Instr::Vadd {
                    rows: rng.range(1, 500) as u32,
                    cols: rng.range(1, 100) as u16,
                    act,
                },
                4 => Instr::Act {
                    rows: rng.range(1, 500) as u32,
                    cols: rng.range(1, 100) as u16,
                    act,
                },
                _ => Instr::Init {
                    rows: rng.range(1, 500) as u32,
                    cols: rng.range(1, 100) as u16,
                    aggop: AggOp::Sum,
                },
            };
            for &p in &[8usize, 16, 32] {
                let want = instr_cycles(&instr, p);
                let got = expand(&instr, p).count() as u64;
                crate::prop_assert!(got == want, "{instr:?} p={p}: {got} != {want}");
            }
            Ok(())
        });
    }

    #[test]
    fn psys_scaling_monotone() {
        let s = Instr::Spdmm {
            n_edges: 4096,
            feat: 128,
            aggop: AggOp::Sum,
            act: Activation::None,
        };
        assert!(instr_cycles(&s, 8) > instr_cycles(&s, 16));
        assert!(instr_cycles(&s, 16) > instr_cycles(&s, 32));
    }
}
