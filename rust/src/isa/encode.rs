//! Bit-exact 128-bit wire encoding of high-level instructions (Fig. 3).
//!
//! Layout (little-endian u128; bit 0 is LSB):
//!
//! ```text
//! [0..8)    OPCODE
//! CSI:      [8..24) layer_id  [24..32) layer_type  [32..64) n_tiling_blocks
//! MemRead:  [8..12) buf  [12..13) lock  [16..56) addr(40b)  [64..96) bytes
//! MemWrite: [8..12) buf              [16..56) addr(40b)  [64..96) bytes
//! GEMM:     [8..40) rows  [40..56) len  [56..72) cols  [72..80) act
//!           [80..81) accumulate
//! SpDMM:    [8..40) n_edges  [40..56) feat  [56..64) aggop  [64..72) act
//! SDDMM:    [8..40) n_edges  [40..56) feat  [56..64) act
//! VADD/ACT: [8..40) rows  [40..56) cols  [56..64) act
//! INIT:     [8..40) rows  [40..56) cols  [56..64) aggop
//! HALT:     opcode only
//! ```

use super::instr::{AggOp, Activation, BufferId, Instr, Opcode};
use anyhow::{anyhow, bail, Result};

/// Instruction width in bytes (128 bits, Sec. 5.3.1).
pub const INSTR_BYTES: usize = 16;

#[inline]
fn put(word: &mut u128, lo: u32, width: u32, value: u128) {
    debug_assert!(width == 128 || value < (1u128 << width), "field overflow");
    *word |= value << lo;
}

#[inline]
fn get(word: u128, lo: u32, width: u32) -> u128 {
    (word >> lo) & if width == 128 { u128::MAX } else { (1u128 << width) - 1 }
}

/// Encode to the 16-byte little-endian wire format.
pub fn encode(instr: &Instr) -> [u8; INSTR_BYTES] {
    let mut w: u128 = 0;
    put(&mut w, 0, 8, instr.opcode() as u8 as u128);
    match *instr {
        Instr::Csi { layer_id, layer_type, n_tiling_blocks } => {
            put(&mut w, 8, 16, layer_id as u128);
            put(&mut w, 24, 8, layer_type as u128);
            put(&mut w, 32, 32, n_tiling_blocks as u128);
        }
        Instr::MemRead { buf, addr, bytes, lock } => {
            put(&mut w, 8, 4, buf as u8 as u128);
            put(&mut w, 12, 1, lock as u128);
            assert!(addr < (1u64 << 40), "DDR address beyond 40 bits");
            put(&mut w, 16, 40, addr as u128);
            put(&mut w, 64, 32, bytes as u128);
        }
        Instr::MemWrite { buf, addr, bytes } => {
            put(&mut w, 8, 4, buf as u8 as u128);
            assert!(addr < (1u64 << 40), "DDR address beyond 40 bits");
            put(&mut w, 16, 40, addr as u128);
            put(&mut w, 64, 32, bytes as u128);
        }
        Instr::Gemm { rows, len, cols, act, accumulate } => {
            put(&mut w, 8, 32, rows as u128);
            put(&mut w, 40, 16, len as u128);
            put(&mut w, 56, 16, cols as u128);
            put(&mut w, 72, 8, act as u8 as u128);
            put(&mut w, 80, 1, accumulate as u128);
        }
        Instr::Spdmm { n_edges, feat, aggop, act } => {
            put(&mut w, 8, 32, n_edges as u128);
            put(&mut w, 40, 16, feat as u128);
            put(&mut w, 56, 8, aggop as u8 as u128);
            put(&mut w, 64, 8, act as u8 as u128);
        }
        Instr::Sddmm { n_edges, feat, act } => {
            put(&mut w, 8, 32, n_edges as u128);
            put(&mut w, 40, 16, feat as u128);
            put(&mut w, 56, 8, act as u8 as u128);
        }
        Instr::Vadd { rows, cols, act } | Instr::Act { rows, cols, act } => {
            put(&mut w, 8, 32, rows as u128);
            put(&mut w, 40, 16, cols as u128);
            put(&mut w, 56, 8, act as u8 as u128);
        }
        Instr::Init { rows, cols, aggop } => {
            put(&mut w, 8, 32, rows as u128);
            put(&mut w, 40, 16, cols as u128);
            put(&mut w, 56, 8, aggop as u8 as u128);
        }
        Instr::Halt => {}
    }
    w.to_le_bytes()
}

/// Decode a 16-byte word; errors on unknown opcodes or enum values
/// (corrupt binaries must not panic the runtime).
pub fn decode(bytes: &[u8; INSTR_BYTES]) -> Result<Instr> {
    let w = u128::from_le_bytes(*bytes);
    let op = Opcode::from_u8(get(w, 0, 8) as u8)
        .ok_or_else(|| anyhow!("unknown opcode {}", get(w, 0, 8)))?;
    let act = |lo: u32| -> Result<Activation> {
        Activation::from_u8(get(w, lo, 8) as u8)
            .ok_or_else(|| anyhow!("bad activation at bit {lo}"))
    };
    let aggop = |lo: u32| -> Result<AggOp> {
        AggOp::from_u8(get(w, lo, 8) as u8)
            .ok_or_else(|| anyhow!("bad aggop at bit {lo}"))
    };
    Ok(match op {
        Opcode::Csi => Instr::Csi {
            layer_id: get(w, 8, 16) as u16,
            layer_type: get(w, 24, 8) as u8,
            n_tiling_blocks: get(w, 32, 32) as u32,
        },
        Opcode::MemRead => Instr::MemRead {
            buf: BufferId::from_u8(get(w, 8, 4) as u8)
                .ok_or_else(|| anyhow!("bad buffer id"))?,
            lock: get(w, 12, 1) != 0,
            addr: get(w, 16, 40) as u64,
            bytes: get(w, 64, 32) as u32,
        },
        Opcode::MemWrite => Instr::MemWrite {
            buf: BufferId::from_u8(get(w, 8, 4) as u8)
                .ok_or_else(|| anyhow!("bad buffer id"))?,
            addr: get(w, 16, 40) as u64,
            bytes: get(w, 64, 32) as u32,
        },
        Opcode::Gemm => Instr::Gemm {
            rows: get(w, 8, 32) as u32,
            len: get(w, 40, 16) as u16,
            cols: get(w, 56, 16) as u16,
            act: act(72)?,
            accumulate: get(w, 80, 1) != 0,
        },
        Opcode::Spdmm => Instr::Spdmm {
            n_edges: get(w, 8, 32) as u32,
            feat: get(w, 40, 16) as u16,
            aggop: aggop(56)?,
            act: act(64)?,
        },
        Opcode::Sddmm => Instr::Sddmm {
            n_edges: get(w, 8, 32) as u32,
            feat: get(w, 40, 16) as u16,
            act: act(56)?,
        },
        Opcode::Vadd => Instr::Vadd {
            rows: get(w, 8, 32) as u32,
            cols: get(w, 40, 16) as u16,
            act: act(56)?,
        },
        Opcode::Act => Instr::Act {
            rows: get(w, 8, 32) as u32,
            cols: get(w, 40, 16) as u16,
            act: act(56)?,
        },
        Opcode::Init => Instr::Init {
            rows: get(w, 8, 32) as u32,
            cols: get(w, 40, 16) as u16,
            aggop: aggop(56)?,
        },
        Opcode::Halt => {
            if get(w, 8, 120) != 0 {
                bail!("HALT with non-zero payload");
            }
            Instr::Halt
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{forall, Rng};

    fn arbitrary_instr(rng: &mut Rng) -> Instr {
        let act = Activation::from_u8(rng.below(8) as u8).unwrap();
        let aggop = AggOp::from_u8(rng.below(4) as u8).unwrap();
        match rng.below(10) {
            0 => Instr::Csi {
                layer_id: rng.below(1 << 16) as u16,
                layer_type: rng.below(6) as u8,
                n_tiling_blocks: rng.next_u64() as u32,
            },
            1 => Instr::MemRead {
                buf: BufferId::from_u8(rng.below(8) as u8).unwrap(),
                addr: rng.below(1 << 40),
                bytes: rng.next_u64() as u32,
                lock: rng.below(2) == 1,
            },
            2 => Instr::MemWrite {
                buf: BufferId::from_u8(rng.below(8) as u8).unwrap(),
                addr: rng.below(1 << 40),
                bytes: rng.next_u64() as u32,
            },
            3 => Instr::Gemm {
                rows: rng.next_u64() as u32,
                len: rng.below(1 << 16) as u16,
                cols: rng.below(1 << 16) as u16,
                act,
                accumulate: rng.below(2) == 1,
            },
            4 => Instr::Spdmm {
                n_edges: rng.next_u64() as u32,
                feat: rng.below(1 << 16) as u16,
                aggop,
                act,
            },
            5 => Instr::Sddmm {
                n_edges: rng.next_u64() as u32,
                feat: rng.below(1 << 16) as u16,
                act,
            },
            6 => Instr::Vadd {
                rows: rng.next_u64() as u32,
                cols: rng.below(1 << 16) as u16,
                act,
            },
            7 => Instr::Act {
                rows: rng.next_u64() as u32,
                cols: rng.below(1 << 16) as u16,
                act,
            },
            8 => Instr::Init {
                rows: rng.next_u64() as u32,
                cols: rng.below(1 << 16) as u16,
                aggop,
            },
            _ => Instr::Halt,
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        forall("isa-roundtrip", 500, |rng| {
            let instr = arbitrary_instr(rng);
            let wire = encode(&instr);
            let back = decode(&wire).map_err(|e| e.to_string())?;
            crate::prop_assert!(back == instr, "{instr:?} != {back:?}");
            Ok(())
        });
    }

    #[test]
    fn decode_rejects_unknown_opcode() {
        let mut wire = [0u8; INSTR_BYTES];
        wire[0] = 0xFF;
        assert!(decode(&wire).is_err());
    }

    #[test]
    fn decode_rejects_bad_enum_field() {
        let instr = Instr::Spdmm {
            n_edges: 10,
            feat: 16,
            aggop: AggOp::Sum,
            act: Activation::Relu,
        };
        let mut wire = encode(&instr);
        wire[7] = 0xEE; // clobber aggop field (bits 56..64)
        assert!(decode(&wire).is_err());
    }

    #[test]
    fn halt_is_canonical_zero_payload() {
        let wire = encode(&Instr::Halt);
        assert_eq!(&wire[1..], &[0u8; 15]);
        assert_eq!(decode(&wire).unwrap(), Instr::Halt);
    }

    #[test]
    fn instructions_are_128_bits() {
        assert_eq!(INSTR_BYTES * 8, 128);
    }
}
