//! `.ga` disassembler: human-readable listing of a compiled program —
//! the debugging view of the ISA (CLI: `graphagile disasm`).

use super::binary::Program;
use super::instr::Instr;

/// One instruction as assembly-ish text.
pub fn format_instr(i: &Instr) -> String {
    match i {
        Instr::Csi { layer_id, layer_type, n_tiling_blocks } => format!(
            "CSI     layer={layer_id} type={layer_type} blocks={n_tiling_blocks}"
        ),
        Instr::MemRead { buf, addr, bytes, lock } => format!(
            "LD      {buf:?} <- ddr[{addr:#x}] {bytes}B{}",
            if *lock { " lock" } else { "" }
        ),
        Instr::MemWrite { buf, addr, bytes } => {
            format!("ST      {buf:?} -> ddr[{addr:#x}] {bytes}B")
        }
        Instr::Gemm { rows, len, cols, act, accumulate } => format!(
            "GEMM    {rows}x{len}x{cols} act={act:?}{}",
            if *accumulate { " acc" } else { "" }
        ),
        Instr::Spdmm { n_edges, feat, aggop, act } => {
            format!("SPDMM   e={n_edges} f={feat} {aggop:?} act={act:?}")
        }
        Instr::Sddmm { n_edges, feat, act } => {
            format!("SDDMM   e={n_edges} f={feat} act={act:?}")
        }
        Instr::Vadd { rows, cols, act } => format!("VADD    {rows}x{cols} act={act:?}"),
        Instr::Act { rows, cols, act } => format!("ACT     {rows}x{cols} {act:?}"),
        Instr::Init { rows, cols, aggop } => format!("INIT    {rows}x{cols} {aggop:?}"),
        Instr::Halt => "HALT".to_string(),
    }
}

/// Full program listing. `max_blocks_per_layer` truncates huge layers
/// (0 = everything).
pub fn disassemble(p: &Program, max_blocks_per_layer: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "; model={} graph={} N1={} N2={} ({} instrs, {} bytes)\n",
        p.model_name,
        p.graph_name,
        p.n1,
        p.n2,
        p.total_instrs(),
        p.size_bytes(),
    ));
    for (li, layer) in p.layers.iter().enumerate() {
        out.push_str(&format!("\nL{li:03}: {}\n", format_instr(&layer.csi)));
        let shown = if max_blocks_per_layer == 0 {
            layer.blocks.len()
        } else {
            layer.blocks.len().min(max_blocks_per_layer)
        };
        for (bi, block) in layer.blocks[..shown].iter().enumerate() {
            out.push_str(&format!("  .block {bi} ({} instrs)\n", block.instrs.len()));
            for instr in &block.instrs {
                out.push_str(&format!("    {}\n", format_instr(instr)));
            }
        }
        if shown < layer.blocks.len() {
            out.push_str(&format!(
                "  ... {} more blocks elided\n",
                layer.blocks.len() - shown
            ));
        }
    }
    out.push_str("\nHALT\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::config::HwConfig;
    use crate::graph::dataset;
    use crate::ir::ZooModel;

    #[test]
    fn disassembles_compiled_program() {
        let ds = dataset("CO").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let exe = compile(
            &ZooModel::B1.build(ds.meta()),
            &tiles,
            &hw,
            CompileOptions::default(),
        );
        let text = disassemble(&exe.program, 2);
        assert!(text.contains("CSI"));
        assert!(text.contains("SPDMM"));
        assert!(text.contains("GEMM"));
        assert!(text.contains("HALT"));
        assert!(text.contains("model=b1"));
    }

    #[test]
    fn truncation_elides() {
        let ds = dataset("PU").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let exe = compile(
            &ZooModel::B2.build(ds.meta()),
            &tiles,
            &hw,
            CompileOptions::default(),
        );
        let text = disassemble(&exe.program, 1);
        assert!(text.contains("more blocks elided"));
        let full = disassemble(&exe.program, 0);
        assert!(!full.contains("elided"));
        assert!(full.len() > text.len());
    }

    #[test]
    fn every_variant_formats() {
        use crate::isa::{Activation, AggOp, BufferId};
        let variants = [
            Instr::Csi { layer_id: 1, layer_type: 0, n_tiling_blocks: 2 },
            Instr::MemRead { buf: BufferId::Edge0, addr: 16, bytes: 8, lock: true },
            Instr::MemWrite { buf: BufferId::Result, addr: 0, bytes: 8 },
            Instr::Gemm { rows: 1, len: 2, cols: 3, act: Activation::Relu, accumulate: true },
            Instr::Spdmm { n_edges: 9, feat: 4, aggop: AggOp::Max, act: Activation::None },
            Instr::Sddmm { n_edges: 9, feat: 4, act: Activation::None },
            Instr::Vadd { rows: 2, cols: 2, act: Activation::None },
            Instr::Act { rows: 2, cols: 2, act: Activation::Elu },
            Instr::Init { rows: 2, cols: 2, aggop: AggOp::Sum },
            Instr::Halt,
        ];
        for v in variants {
            assert!(!format_instr(&v).is_empty());
        }
    }
}
