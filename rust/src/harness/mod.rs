//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (Sec. 8). Each public function returns structured rows and
//! can render them as a markdown table; `graphagile tables --id <ID>`
//! and the `rust/benches/*` binaries drive these.
//!
//! | ID  | Paper artifact                                   |
//! |-----|--------------------------------------------------|
//! | t4  | Table 4 — dataset statistics                     |
//! | t5  | Table 5 — model zoo                              |
//! | t7  | Table 7 — T_E2E / T_LoC / T_LoH per model x graph|
//! | t8  | Table 8 — binary sizes                           |
//! | t9  | Table 9 — qualitative comparison                 |
//! | t10 | Table 10 — LoH vs HyGCN / AWB-GCN / BoostGCN     |
//! | f14 | Fig. 14 — computation-order optimization impact  |
//! | f15 | Fig. 15 — layer-fusion impact                    |
//! | f16 | Fig. 16 — comp/comm overlap impact               |
//! | f17 | Fig. 17 — E2E vs DGL (CPU/GPU)                   |
//! | f18 | Fig. 18 — E2E vs PyG (CPU/GPU), with OOM cells   |

#![warn(missing_docs)]

pub mod bench_support;
pub mod render;
pub mod tables;

pub use render::{divergence_report, markdown, replay_summary, serve_summary};
pub use tables::*;
