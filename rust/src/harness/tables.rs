//! Table/figure generators (see module docs in `harness`).

use super::render::{markdown, ms};
use crate::baselines::{
    awb_gcn_loh, boostgcn_loh, framework_e2e, hygcn_loh, Framework,
    Processor,
};
use crate::compiler::{compile, CompileOptions, Executable};
use crate::config::HwConfig;
use crate::graph::{Dataset, TileCounts, ALL_DATASETS};
use crate::ir::{ZooModel, ALL_MODELS};
use crate::sim::{comm_seconds, simulate, SimResult};
use crate::util::timed;
use std::collections::HashMap;

/// Shared context: hardware config + per-dataset tile-count cache with
/// the measured partitioning time (the dominant T_LoC term, O(|V|+|E|)).
pub struct Ctx {
    /// Hardware configuration every cell compiles and simulates for.
    pub hw: HwConfig,
    /// Scale divisor for the synthetic datasets (1 = paper-scale; CI
    /// uses a larger divisor to keep test runs fast).
    pub scale: u64,
    cache: HashMap<&'static str, (TileCounts, f64)>,
}

impl Ctx {
    /// A context on the paper's Alveo U250 config at the given scale.
    pub fn new(scale: u64) -> Ctx {
        Ctx { hw: HwConfig::alveo_u250(), scale, cache: HashMap::new() }
    }

    /// The dataset as served at this context's scale divisor.
    pub fn dataset(&self, d: Dataset) -> Dataset {
        if self.scale > 1 {
            d.scaled(self.scale)
        } else {
            d
        }
    }

    /// Tile counts + partitioning seconds for a dataset (cached).
    ///
    /// Edge generation (the synthetic stand-in for loading the dataset
    /// from disk) is *not* part of T_LoC; only the O(|E|) Fiber-Shard
    /// histogram pass is timed, matching the paper's definition of the
    /// compiler's data-partitioning cost.
    pub fn tiles(&mut self, d: &Dataset) -> (TileCounts, f64) {
        let n1 = self.hw.n1() as u64;
        let scaled = self.dataset(*d);
        let entry = self.cache.entry(d.key).or_insert_with(|| {
            let (src, dst) = scaled.edge_arrays();
            let (tc, secs) =
                timed(|| TileCounts::from_edges(&src, &dst, scaled.n_vertices, n1));
            (tc, secs)
        });
        (entry.0.clone(), entry.1)
    }

    /// Compile + simulate one (model, dataset) cell. The returned T_LoC
    /// combines the measured O(|E|) partitioning pass (`t_part`, real
    /// wall-clock — it still varies with build profile and load) with
    /// the *modeled* deterministic compiler-pass total
    /// (`CompileReport::total`), so only the partitioning share of a
    /// regenerated table can wobble between runs.
    pub fn run_cell(
        &mut self,
        model: ZooModel,
        d: &Dataset,
        opts: CompileOptions,
        overlap: bool,
    ) -> (Executable, SimResult, f64) {
        let (tiles, t_part) = self.tiles(d);
        let ir = model.build(self.dataset(*d).meta());
        let hw = HwConfig { overlap, ..self.hw.clone() };
        let exe = compile(&ir, &tiles, &hw, opts);
        let sim = simulate(&exe.program, &hw);
        let t_loc = t_part + exe.report.total();
        (exe, sim, t_loc)
    }
}

// ---------------------------------------------------------------------------
// Table 4 / Table 5 (static descriptions)
// ---------------------------------------------------------------------------

/// Table 4 — dataset statistics (static).
pub fn table4() -> String {
    let rows: Vec<Vec<String>> = ALL_DATASETS
        .iter()
        .map(|d| {
            vec![
                format!("{} ({})", d.name, d.key),
                d.n_vertices.to_string(),
                d.n_edges.to_string(),
                d.feat_len.to_string(),
                d.n_classes.to_string(),
            ]
        })
        .collect();
    markdown(&["Dataset", "Vertices", "Edges", "Features", "Classes"], &rows)
}

/// Table 5 — the b1-b8 model zoo (static).
pub fn table5() -> String {
    let rows = vec![
        vec!["b1", "GCN", "2", "16"],
        vec!["b2", "GCN", "2", "128"],
        vec!["b3", "GraphSAGE", "2", "128"],
        vec!["b4", "GraphSAGE", "2", "256"],
        vec!["b5", "GIN", "5", "128"],
        vec!["b6", "GAT", "2", "64"],
        vec!["b7", "SGC", "1 (k=2)", "-"],
        vec!["b8", "GraphGym", "1+3+1", "256"],
    ]
    .into_iter()
    .map(|r| r.into_iter().map(String::from).collect())
    .collect::<Vec<_>>();
    markdown(&["Model", "Layer type", "Layers", "Hidden"], &rows)
}

// ---------------------------------------------------------------------------
// Table 7 — end-to-end latency
// ---------------------------------------------------------------------------

/// One Table 7 cell: the end-to-end latency split of (model, dataset).
#[derive(Clone, Debug)]
pub struct T7Row {
    /// Model key (b1-b8).
    pub model: &'static str,
    /// Dataset key (Table 4 abbreviation).
    pub dataset: &'static str,
    /// End-to-end seconds: `t_loc + t_comm + t_loh`.
    pub t_e2e: f64,
    /// Latency of compilation (partitioning + compiler passes).
    pub t_loc: f64,
    /// Host→device communication seconds.
    pub t_comm: f64,
    /// Latency on hardware (simulated cycles / freq).
    pub t_loh: f64,
}

/// Table 7 rows for the given (model, dataset) grid.
pub fn table7_rows(ctx: &mut Ctx, models: &[ZooModel], datasets: &[Dataset]) -> Vec<T7Row> {
    let mut rows = Vec::new();
    for m in models {
        for d in datasets {
            let (exe, sim, t_loc) = ctx.run_cell(*m, d, CompileOptions::default(), true);
            let scaled = ctx.dataset(*d);
            let bytes = scaled.meta().input_bytes()
                + exe.ir.weight_bytes()
                + exe.program.size_bytes();
            let t_comm = comm_seconds(&ctx.hw, bytes);
            let t_loh = sim.loh_seconds();
            rows.push(T7Row {
                model: m.key(),
                dataset: d.key,
                t_e2e: t_loc + t_comm + t_loh,
                t_loc,
                t_comm,
                t_loh,
            });
        }
    }
    rows
}

/// Table 7 — end-to-end latency, rendered over the full zoo x datasets.
pub fn table7(ctx: &mut Ctx) -> String {
    let rows = table7_rows(ctx, &ALL_MODELS, &ALL_DATASETS);
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.to_string(),
                r.dataset.to_string(),
                ms(r.t_e2e),
                ms(r.t_loc),
                ms(r.t_comm),
                ms(r.t_loh),
            ]
        })
        .collect();
    markdown(
        &["Model", "Dataset", "T_E2E (ms)", "T_LoC (ms)", "T_comm (ms)", "T_LoH (ms)"],
        &cells,
    )
}

// ---------------------------------------------------------------------------
// Table 8 — binary sizes
// ---------------------------------------------------------------------------

/// Table 8 rows: per-model binary MB per dataset, plus the input row.
pub fn table8_rows(ctx: &mut Ctx) -> Vec<(String, Vec<f64>)> {
    let mut rows = Vec::new();
    for m in ALL_MODELS {
        let mut sizes = Vec::new();
        for d in ALL_DATASETS {
            let (exe, _, _) = ctx.run_cell(m, &d, CompileOptions::default(), true);
            sizes.push(exe.program.size_bytes() as f64 / 1e6);
        }
        rows.push((m.key().to_string(), sizes));
    }
    let input: Vec<f64> = ALL_DATASETS
        .iter()
        .map(|d| ctx.dataset(*d).meta().input_bytes() as f64 / 1e6)
        .collect();
    rows.push(("input graph".to_string(), input));
    rows
}

/// Table 8 — binary sizes, rendered.
pub fn table8(ctx: &mut Ctx) -> String {
    let rows = table8_rows(ctx);
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|(name, sizes)| {
            let mut row = vec![name.clone()];
            row.extend(sizes.iter().map(|s| format!("{s:.3}")));
            row
        })
        .collect();
    markdown(&["MB", "CI", "CO", "PU", "FL", "RE", "YE", "AP"], &cells)
}

// ---------------------------------------------------------------------------
// Figs. 14-16 — optimization ablations (average speedup % per model)
// ---------------------------------------------------------------------------

fn ablation(ctx: &mut Ctx, datasets: &[Dataset], variant: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for m in ALL_MODELS {
        let mut speedups = Vec::new();
        for d in datasets {
            let on = CompileOptions::default();
            let (off, overlap_off) = match variant {
                "order" => (CompileOptions { order_opt: false, ..on }, true),
                "fusion" => (CompileOptions { fusion: false, ..on }, true),
                "overlap" => (on, false),
                _ => unreachable!(),
            };
            let (_, sim_on, _) = ctx.run_cell(m, d, on, true);
            let (_, sim_off, _) = ctx.run_cell(m, d, off, overlap_off);
            speedups.push(sim_off.cycles as f64 / sim_on.cycles as f64);
        }
        let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
        out.push((m.key().to_string(), (avg - 1.0) * 100.0));
    }
    out
}

/// Fig. 14 rows: per-model average LoH speedup % from order opt.
pub fn fig14_rows(ctx: &mut Ctx, datasets: &[Dataset]) -> Vec<(String, f64)> {
    ablation(ctx, datasets, "order")
}

/// Fig. 15 rows: per-model average LoH speedup % from layer fusion.
pub fn fig15_rows(ctx: &mut Ctx, datasets: &[Dataset]) -> Vec<(String, f64)> {
    ablation(ctx, datasets, "fusion")
}

/// Fig. 16 rows: per-model average LoH speedup % from comp/comm overlap.
pub fn fig16_rows(ctx: &mut Ctx, datasets: &[Dataset]) -> Vec<(String, f64)> {
    ablation(ctx, datasets, "overlap")
}

fn fig_markdown(rows: &[(String, f64)], what: &str) -> String {
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|(m, pct)| vec![m.clone(), format!("{pct:.1}%")])
        .collect();
    markdown(&["Model", what], &cells)
}

/// Fig. 14 — order-optimization ablation, rendered.
pub fn fig14(ctx: &mut Ctx, datasets: &[Dataset]) -> String {
    fig_markdown(&fig14_rows(ctx, datasets), "avg LoH speedup from order opt")
}

/// Fig. 15 — layer-fusion ablation, rendered.
pub fn fig15(ctx: &mut Ctx, datasets: &[Dataset]) -> String {
    fig_markdown(&fig15_rows(ctx, datasets), "avg LoH speedup from fusion")
}

/// Fig. 16 — comp/comm-overlap ablation, rendered.
pub fn fig16(ctx: &mut Ctx, datasets: &[Dataset]) -> String {
    fig_markdown(&fig16_rows(ctx, datasets), "avg LoH speedup from overlap")
}

// ---------------------------------------------------------------------------
// Figs. 17-18 — cross-platform comparison
// ---------------------------------------------------------------------------

/// One Figs. 17-18 cell: framework E2E seconds vs GraphAGILE's.
#[derive(Clone, Debug)]
pub struct CrossRow {
    /// Model key (b1-b8).
    pub model: &'static str,
    /// Dataset key (Table 4 abbreviation).
    pub dataset: &'static str,
    /// Framework-on-CPU E2E seconds; `None` renders as the paper's OOM.
    pub cpu: Option<f64>,
    /// Framework-on-GPU E2E seconds; `None` renders as the paper's OOM.
    pub gpu: Option<f64>,
    /// GraphAGILE E2E seconds (T_LoC + T_comm + T_LoH).
    pub graphagile: f64,
}

/// Figs. 17-18 rows: framework CPU/GPU baselines vs GraphAGILE E2E.
pub fn cross_platform_rows(
    ctx: &mut Ctx,
    fw: Framework,
    models: &[ZooModel],
    datasets: &[Dataset],
) -> Vec<CrossRow> {
    let mut rows = Vec::new();
    for m in models {
        for d in datasets {
            let ir = m.build(ctx.dataset(*d).meta());
            let cpu = framework_e2e(&ir, fw, Processor::Cpu).seconds();
            let gpu = framework_e2e(&ir, fw, Processor::Gpu).seconds();
            let (exe, sim, t_loc) = ctx.run_cell(*m, d, CompileOptions::default(), true);
            let bytes = ctx.dataset(*d).meta().input_bytes()
                + exe.ir.weight_bytes()
                + exe.program.size_bytes();
            let ga = t_loc + comm_seconds(&ctx.hw, bytes) + sim.loh_seconds();
            rows.push(CrossRow {
                model: m.key(),
                dataset: d.key,
                cpu,
                gpu,
                graphagile: ga,
            });
        }
    }
    rows
}

fn cross_markdown(rows: &[CrossRow], fw: &str) -> String {
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let fmt = |v: Option<f64>| match v {
                Some(s) => ms(s),
                None => "OOM".to_string(),
            };
            let speedup = |v: Option<f64>| match v {
                Some(s) => format!("{:.2}x", s / r.graphagile),
                None => "-".to_string(),
            };
            vec![
                r.model.to_string(),
                r.dataset.to_string(),
                fmt(r.cpu),
                fmt(r.gpu),
                ms(r.graphagile),
                speedup(r.cpu),
                speedup(r.gpu),
            ]
        })
        .collect();
    markdown(
        &[
            "Model",
            "Dataset",
            &format!("{fw}-CPU (ms)"),
            &format!("{fw}-GPU (ms)"),
            "GraphAGILE (ms)",
            "vs CPU",
            "vs GPU",
        ],
        &cells,
    )
}

/// Fig. 17: DGL on b1-b7.
pub fn fig17(ctx: &mut Ctx, datasets: &[Dataset]) -> String {
    let models = &ALL_MODELS[..7];
    let rows = cross_platform_rows(ctx, Framework::Dgl, models, datasets);
    cross_markdown(&rows, "DGL")
}

/// Fig. 18: PyG on b1-b8 (with the paper's OOM cells).
pub fn fig18(ctx: &mut Ctx, datasets: &[Dataset]) -> String {
    let rows = cross_platform_rows(ctx, Framework::PyG, &ALL_MODELS, datasets);
    cross_markdown(&rows, "PyG")
}

// ---------------------------------------------------------------------------
// Table 9 — qualitative comparison (static)
// ---------------------------------------------------------------------------

/// Table 9 — qualitative comparison against prior accelerators (static).
pub fn table9() -> String {
    let rows: Vec<Vec<String>> = vec![
        vec!["HyGCN", "No", "No", "graph partitioning, sparsity elim.", "No", "Yes", "No"],
        vec!["AWB-GCN", "No", "No", "partitioning, layout transform", "Yes", "No", "No"],
        vec!["DeepBurning-GL", "No", "Yes (6-8 h)", "(unknown)", "No", "Yes", "No"],
        vec!["BoostGCN", "No", "Yes (6-8 h)", "graph partitioning", "No", "Yes", "No"],
        vec!["GraphAGILE", "Yes", "No", "software compilation", "Yes", "Yes", "Yes"],
    ]
    .into_iter()
    .map(|r| r.into_iter().map(String::from).collect())
    .collect();
    markdown(
        &["System", "GAT", "NHC*", "Preprocessing", "UFH", "GEMM", "SDDMM"],
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Table 10 — accelerator LoH comparison (b2 on FL/RE/YE/AP)
// ---------------------------------------------------------------------------

/// One Table 10 cell: accelerator LoH seconds for b2 on one dataset.
#[derive(Clone, Debug)]
pub struct T10Row {
    /// Dataset key (FL / RE / YE / AP).
    pub dataset: &'static str,
    /// BoostGCN LoH seconds (reported on every Table 10 dataset).
    pub boostgcn: f64,
    /// HyGCN LoH seconds; the paper reports it on Reddit only.
    pub hygcn: Option<f64>,
    /// AWB-GCN LoH seconds; the paper reports it on Reddit only.
    pub awb_gcn: Option<f64>,
    /// GraphAGILE simulated LoH seconds.
    pub graphagile: f64,
}

/// Table 10 rows: b2 LoH vs the published accelerator numbers.
pub fn table10_rows(ctx: &mut Ctx) -> Vec<T10Row> {
    let mut rows = Vec::new();
    for d in ALL_DATASETS.iter().filter(|d| matches!(d.key, "FL" | "RE" | "YE" | "AP")) {
        let ir = ZooModel::B2.build(ctx.dataset(*d).meta());
        let (_, sim, _) = ctx.run_cell(ZooModel::B2, d, CompileOptions::default(), true);
        rows.push(T10Row {
            dataset: d.key,
            boostgcn: boostgcn_loh(&ir),
            // The paper reports HyGCN / AWB-GCN on Reddit only.
            hygcn: (d.key == "RE").then(|| hygcn_loh(&ir)),
            awb_gcn: (d.key == "RE").then(|| awb_gcn_loh(&ir)),
            graphagile: sim.loh_seconds(),
        });
    }
    rows
}

/// Table 10 — accelerator LoH comparison, rendered.
pub fn table10(ctx: &mut Ctx) -> String {
    let rows = table10_rows(ctx);
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let opt = |v: Option<f64>| v.map(ms).unwrap_or_else(|| "-".into());
            vec![
                r.dataset.to_string(),
                ms(r.boostgcn),
                opt(r.hygcn),
                opt(r.awb_gcn),
                ms(r.graphagile),
                format!("{:.2}x", r.boostgcn / r.graphagile),
            ]
        })
        .collect();
    markdown(
        &["Dataset", "BoostGCN (ms)", "HyGCN (ms)", "AWB-GCN (ms)", "GraphAGILE (ms)", "vs BoostGCN"],
        &cells,
    )
}

/// Dispatch by table/figure id (the CLI's `tables --id`).
pub fn by_id(ctx: &mut Ctx, id: &str, datasets: &[Dataset]) -> Option<String> {
    Some(match id {
        "t4" => table4(),
        "t5" => table5(),
        "t7" => table7(ctx),
        "t8" => table8(ctx),
        "t9" => table9(),
        "t10" => table10(ctx),
        "f14" => fig14(ctx, datasets),
        "f15" => fig15(ctx, datasets),
        "f16" => fig16(ctx, datasets),
        "f17" => fig17(ctx, datasets),
        "f18" => fig18(ctx, datasets),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;

    fn small_ctx() -> Ctx {
        // Scale datasets down 64x so CI stays fast; shapes still hold.
        Ctx::new(64)
    }

    fn small_sets() -> Vec<Dataset> {
        ["CO", "PU"].iter().map(|k| dataset(k).unwrap()).collect()
    }

    #[test]
    fn table7_cells_are_consistent() {
        let mut ctx = small_ctx();
        let rows = table7_rows(&mut ctx, &[ZooModel::B1, ZooModel::B2], &small_sets());
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.t_e2e >= r.t_loh && r.t_e2e >= r.t_loc, "{r:?}");
            assert!((r.t_e2e - (r.t_loc + r.t_comm + r.t_loh)).abs() < 1e-12);
            assert!(r.t_loh > 0.0);
        }
    }

    #[test]
    fn fig14_shapes_match_paper() {
        // Order opt: b7 (SGC) benefits most; b8 sees ~0 (pre-MLP
        // equalizes widths) — the paper's Fig. 14 signature.
        let mut ctx = small_ctx();
        let sets: Vec<Dataset> = ["CI", "CO"].iter().map(|k| dataset(k).unwrap()).collect();
        let rows = fig14_rows(&mut ctx, &sets);
        let get = |k: &str| rows.iter().find(|(m, _)| m == k).unwrap().1;
        assert!(get("b7") > 50.0, "b7 order-opt speedup {}", get("b7"));
        assert!(get("b8") < 5.0, "b8 should be ~0, got {}", get("b8"));
        assert!(get("b1") > get("b5"), "b1 {} vs b5 {}", get("b1"), get("b5"));
    }

    #[test]
    fn fig16_overlap_positive_everywhere() {
        let mut ctx = small_ctx();
        let rows = fig16_rows(&mut ctx, &small_sets());
        for (m, pct) in &rows {
            assert!(*pct > 0.0, "{m}: overlap speedup {pct}%");
        }
    }

    #[test]
    fn cross_platform_graphagile_wins_cpu() {
        // At tiny scales fixed overheads dominate; use a moderately
        // sized graph (FL/16 ~ 56K edges) where the paper's ordering
        // (GraphAGILE < CPU frameworks) must already hold.
        // Compare hardware-side latency (LoH + comm): measured compile
        // wall-clock depends on the build profile (debug tests) and is
        // excluded here; the release benches compare full E2E.
        let mut ctx = Ctx::new(16);
        let d = dataset("FL").unwrap();
        let ir = ZooModel::B2.build(ctx.dataset(d).meta());
        let cpu = framework_e2e(&ir, Framework::Dgl, Processor::Cpu)
            .seconds()
            .unwrap();
        let (exe, sim, _) = ctx.run_cell(ZooModel::B2, &d, CompileOptions::default(), true);
        let bytes = ctx.dataset(d).meta().input_bytes()
            + exe.ir.weight_bytes()
            + exe.program.size_bytes();
        let ga = comm_seconds(&ctx.hw, bytes) + sim.loh_seconds();
        assert!(cpu > ga, "DGL-CPU {cpu} vs GraphAGILE hw {ga}");
    }

    #[test]
    fn by_id_dispatch() {
        let mut ctx = small_ctx();
        assert!(by_id(&mut ctx, "t4", &[]).unwrap().contains("Reddit"));
        assert!(by_id(&mut ctx, "t9", &[]).unwrap().contains("GraphAGILE"));
        assert!(by_id(&mut ctx, "nope", &[]).is_none());
    }
}
