//! Minimal renderers for harness output: markdown tables, the
//! serving-fleet summary block (the one place `ServeStats` is turned
//! into text, so every counter the coordinator tracks — including
//! coalesce and kernel re-map telemetry — is actually printed), and
//! the replay summary / divergence report behind
//! `graphagile replay --verify`.

use crate::daemon::{Trace, TraceEvent};
use crate::serve::ServeStats;

/// Render a markdown table.
pub fn markdown(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push('|');
    for h in headers {
        out.push_str(&format!(" {h} |"));
    }
    out.push('\n');
    out.push('|');
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push('|');
        for cell in row {
            out.push_str(&format!(" {cell} |"));
        }
        out.push('\n');
    }
    out
}

/// Format seconds as milliseconds with 3 significant decimals.
pub fn ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

/// Render the fleet counters of a serving run — every `ServeStats`
/// field, one aligned line each. The mini-batch block (sampled
/// neighborhood sizes, bucket hits, micro-batched riders, per-class
/// p50s) only renders when the workload contained mini-batch requests,
/// so whole-graph runs keep their familiar shape — but no counter the
/// coordinator tracks is ever silently dropped.
pub fn serve_summary(stats: &ServeStats) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "  completed         {} ({} mini-batch)\n",
        stats.completed, stats.minibatched
    ));
    // Updates are host-side graph mutations, never cache lookups —
    // the hit denominator is the inference count only (matching
    // `Coordinator::hit_rate`).
    out.push_str(&format!(
        "  cache hits        {} / {} ({} coalesced)\n",
        stats.cache_hits,
        stats.completed - stats.updates,
        stats.coalesced
    ));
    if stats.minibatched > 0 {
        out.push_str(&format!(
            "  bucket hits       {} / {} mini-batch\n",
            stats.bucket_hits, stats.minibatched
        ));
        out.push_str(&format!("  batched riders    {}\n", stats.batched));
        out.push_str(&format!(
            "  sampled           {} vertices, {} edges\n",
            stats.sampled_vertices, stats.sampled_edges
        ));
    }
    if stats.updates > 0 {
        out.push_str(&format!(
            "  updates           {} applied (epoch {}, {} compactions)\n",
            stats.updates, stats.max_epoch, stats.compactions
        ));
        out.push_str(&format!(
            "  dirty subshards   {} ({} edges rebuilt)\n",
            stats.dirty_subshards, stats.rebuilt_edges
        ));
        out.push_str(&format!(
            "  invalidated       {} whole-graph programs\n",
            stats.invalidated
        ));
    }
    out.push_str(&format!("  kernel re-maps    {}\n", stats.remaps));
    if stats.quantized > 0 {
        out.push_str(&format!(
            "  quantized         {} requests ({} int8 visits)\n",
            stats.quantized, stats.quant_visits
        ));
        out.push_str(&format!(
            "  requant ops       {} ({} int8 bytes moved)\n",
            stats.requant_ops, stats.int8_bytes
        ));
    }
    // The fault/degradation block renders only when something actually
    // went wrong: a zero-fault run's summary stays byte-identical to
    // the pre-fault format.
    let faulted = stats.crashes
        + stats.stalls
        + stats.corruptions
        + stats.retries
        + stats.rerouted
        + stats.degraded
        + stats.shed
        > 0
        || stats.downtime > 0.0
        || stats.t_backoff > 0.0;
    if faulted {
        out.push_str(&format!(
            "  faults            {} crashes, {} stalls, {} corruptions ({:.3} s downtime)\n",
            stats.crashes, stats.stalls, stats.corruptions, stats.downtime
        ));
        out.push_str(&format!(
            "  retries           {} ({} re-routed, {} ms backoff)\n",
            stats.retries,
            stats.rerouted,
            ms(stats.t_backoff)
        ));
        out.push_str(&format!(
            "  degraded / shed   {} / {}\n",
            stats.degraded, stats.shed
        ));
    }
    out.push_str(&format!(
        "  latency p50/p99   {} ms / {} ms\n",
        ms(stats.p50),
        ms(stats.p99)
    ));
    if stats.minibatched > 0 {
        out.push_str(&format!(
            "  p50 mini / full   {} ms / {} ms\n",
            ms(stats.p50_mini),
            ms(stats.p50_full)
        ));
    }
    out.push_str(&format!("  mean latency      {} ms\n", ms(stats.mean)));
    out.push_str(&format!(
        "  device busy       {:.3} s over {:.3} s makespan\n",
        stats.device_busy, stats.makespan
    ));
    // The per-tenant block renders only under a tenant config: a
    // tenant-blind run's summary stays byte-identical to the pre-QoS
    // format.
    if !stats.tenants.is_empty() {
        out.push_str(&format!("  tenants           {}\n", stats.tenants.len()));
        for t in &stats.tenants {
            let total = t.completed + t.shed;
            let miss = if total > 0 { t.missed as f64 * 100.0 / total as f64 } else { 0.0 };
            out.push_str(&format!(
                "    tenant {:<5} w {:<4} {} served / {} degraded / {} shed, \
                 p50/p99 {} / {} ms, miss {:.1}%, paced {} ms, busy {:.3} s\n",
                t.tenant,
                t.weight,
                t.completed,
                t.degraded,
                t.shed,
                ms(t.p50),
                ms(t.p99),
                miss,
                ms(t.t_qos),
                t.busy,
            ));
        }
    }
    out
}

/// One-paragraph header for a replayed trace: what was recorded, under
/// what fleet shape, and what the replay produced.
pub fn replay_summary(trace: &Trace, replayed: &ServeStats) -> String {
    let (mut admits, mut stats_q, mut drains) = (0usize, 0usize, 0usize);
    let (mut faults, mut decisions) = (0usize, 0usize);
    for e in &trace.events {
        match e {
            TraceEvent::Admit(_) => admits += 1,
            TraceEvent::Stats { .. } => stats_q += 1,
            TraceEvent::Drain { .. } => drains += 1,
            TraceEvent::Fault(_) => faults += 1,
            TraceEvent::Decision(_) => decisions += 1,
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "trace v{}: {} events ({} admits, {} stats queries, {} drains), \
         {} recorded responses, fleet of {} device(s)\n",
        trace.version,
        trace.events.len(),
        admits,
        stats_q,
        drains,
        trace.responses.len(),
        trace.config.fleet.n_devices,
    ));
    // v2-only line: a fault-free trace keeps the v1 header verbatim.
    // (Decision events under a tenant config belong to the QoS line
    // below, not here.)
    if trace.config.tenants.is_none()
        && (faults + decisions > 0 || trace.config.fault_plan.is_some())
    {
        out.push_str(&format!(
            "  fault plan: {} scheduled event(s); {} fault(s) fired, \
             {} degrade/shed decision(s) recorded\n",
            trace.config.fault_plan.as_ref().map_or(0, |p| p.events.len()),
            faults,
            decisions,
        ));
    }
    // v3-only line: a tenant-free trace keeps the older header verbatim.
    if let Some(t) = &trace.config.tenants {
        out.push_str(&format!(
            "  tenant QoS: {} configured tenant(s), total weight {}, \
             {} degrade/shed decision(s) recorded\n",
            t.tenants.len(),
            t.total_weight(),
            decisions,
        ));
    }
    out.push_str("replayed:\n");
    out.push_str(&serve_summary(replayed));
    out
}

/// Render a verify divergence list: the pass/fail verdict line first,
/// then one named divergence per line — `replay --verify` failures name
/// the exact diverging counter instead of dumping structs.
pub fn divergence_report(divergences: &[String]) -> String {
    if divergences.is_empty() {
        return "verify: PASS — replay is bit-identical to the recorded run\n".to_string();
    }
    let mut out = format!("verify: FAIL — {} divergence(s)\n", divergences.len());
    for d in divergences {
        out.push_str(&format!("  {d}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_shape() {
        let t = markdown(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(t, "| a | b |\n|---|---|\n| 1 | 2 |\n");
    }

    #[test]
    fn ms_format() {
        assert_eq!(ms(0.0123456), "12.346");
    }

    #[test]
    fn serve_summary_prints_every_counter() {
        // Distinct sentinel values per field: the regression this
        // guards is a counter tracked by the coordinator but silently
        // dropped from the rendered table.
        let stats = ServeStats {
            completed: 8,
            cache_hits: 7,
            coalesced: 3,
            minibatched: 5,
            batched: 2,
            bucket_hits: 4,
            sampled_vertices: 123,
            sampled_edges: 456,
            remaps: 42,
            quantized: 3,
            quant_visits: 77,
            requant_ops: 88,
            int8_bytes: 999,
            updates: 6,
            max_epoch: 9,
            dirty_subshards: 11,
            rebuilt_edges: 789,
            invalidated: 13,
            compactions: 1,
            p50: 0.001,
            p99: 0.002,
            mean: 0.0015,
            p50_mini: 0.0005,
            p50_full: 0.003,
            device_busy: 0.5,
            makespan: 1.0,
            retries: 21,
            rerouted: 14,
            degraded: 15,
            shed: 16,
            crashes: 17,
            stalls: 18,
            corruptions: 19,
            downtime: 0.25,
            t_backoff: 0.004,
            tenants: vec![crate::serve::TenantStats {
                tenant: 9,
                weight: 4.0,
                completed: 20,
                degraded: 5,
                shed: 5,
                missed: 10,
                p50: 0.006,
                p99: 0.007,
                t_qos: 0.008,
                busy: 0.125,
            }],
        };
        let s = serve_summary(&stats);
        assert!(s.contains("3 coalesced"), "{s}");
        assert!(s.contains("re-maps    42"), "{s}");
        assert!(s.contains("3 requests (77 int8 visits)"), "{s}");
        assert!(s.contains("requant ops       88 (999 int8 bytes moved)"), "{s}");
        // 6 of the 8 completed requests were updates: the hit-rate
        // denominator is the 2 inference requests.
        assert!(s.contains("7 / 2"), "{s}");
        assert!(s.contains("(5 mini-batch)"), "{s}");
        assert!(s.contains("4 / 5 mini-batch"), "{s}");
        assert!(s.contains("batched riders    2"), "{s}");
        assert!(s.contains("123 vertices, 456 edges"), "{s}");
        assert!(s.contains("6 applied (epoch 9, 1 compactions)"), "{s}");
        assert!(s.contains("11 (789 edges rebuilt)"), "{s}");
        assert!(s.contains("invalidated       13 whole-graph"), "{s}");
        assert!(s.contains("1.000 ms / 2.000 ms"), "{s}");
        assert!(s.contains("mean latency      1.500 ms"), "{s}");
        assert!(s.contains("0.500 ms / 3.000 ms"), "{s}");
        assert!(s.contains("0.500 s over 1.000 s"), "{s}");
        assert!(s.contains("17 crashes, 18 stalls, 19 corruptions (0.250 s downtime)"), "{s}");
        assert!(s.contains("retries           21 (14 re-routed, 4.000 ms backoff)"), "{s}");
        assert!(s.contains("degraded / shed   15 / 16"), "{s}");
        // Every TenantStats field reaches the per-tenant line: 10
        // missed of 25 requests (20 served + 5 shed) is a 40% miss
        // rate.
        assert!(s.contains("tenants           1"), "{s}");
        assert!(
            s.contains(
                "tenant 9     w 4    20 served / 5 degraded / 5 shed, \
                 p50/p99 6.000 / 7.000 ms, miss 40.0%, paced 8.000 ms, busy 0.125 s"
            ),
            "{s}"
        );
    }

    #[test]
    fn replay_summary_counts_event_kinds() {
        use crate::config::HwConfig;
        use crate::graph::dataset;
        use crate::ir::ZooModel;
        use crate::serve::{FleetConfig, Request};
        let mut trace = Trace::from_requests(
            HwConfig::alveo_u250(),
            FleetConfig { n_devices: 2, ..FleetConfig::default() },
            vec![Request::full(0, ZooModel::B1, dataset("CO").unwrap(), 0.0)],
        );
        trace.events.push(TraceEvent::Stats { at: 1.0 });
        trace.events.push(TraceEvent::Drain { at: 2.0 });
        let s = replay_summary(&trace, &ServeStats::default());
        assert!(s.contains("3 events (1 admits, 1 stats queries, 1 drains)"), "{s}");
        assert!(s.contains("fleet of 2 device(s)"), "{s}");
    }

    #[test]
    fn divergence_report_names_each_divergence() {
        assert!(divergence_report(&[]).contains("PASS"));
        let r = divergence_report(&["stats.cache_hits: 5 != 4".to_string()]);
        assert!(r.contains("FAIL — 1 divergence(s)"), "{r}");
        assert!(r.contains("  stats.cache_hits: 5 != 4"), "{r}");
    }

    #[test]
    fn serve_summary_hides_minibatch_block_for_whole_graph_runs() {
        let stats = ServeStats {
            completed: 4,
            cache_hits: 3,
            p50: 0.001,
            p99: 0.002,
            mean: 0.0015,
            ..ServeStats::default()
        };
        let s = serve_summary(&stats);
        assert!(s.contains("(0 mini-batch)"), "{s}");
        assert!(!s.contains("bucket hits"), "{s}");
        assert!(!s.contains("p50 mini"), "{s}");
        assert!(!s.contains("updates"), "{s}");
        assert!(!s.contains("dirty subshards"), "{s}");
        assert!(!s.contains("quantized"), "{s}");
        // A fault-free run also keeps the pre-fault summary shape.
        assert!(!s.contains("faults"), "{s}");
        assert!(!s.contains("retries"), "{s}");
        assert!(!s.contains("shed"), "{s}");
        // And a tenant-blind run keeps the pre-QoS shape.
        assert!(!s.contains("tenant"), "{s}");
    }

    #[test]
    fn replay_summary_names_fault_plan_and_fired_events() {
        use crate::config::HwConfig;
        use crate::graph::dataset;
        use crate::ir::ZooModel;
        use crate::serve::{
            DecisionRecord, FaultEvent, FaultPlan, FaultRecord, FleetConfig, Outcome, Request,
            ShedReason,
        };
        let mut trace = Trace::from_requests(
            HwConfig::alveo_u250(),
            FleetConfig::default(),
            vec![Request::full(0, ZooModel::B1, dataset("CO").unwrap(), 0.0)],
        );
        trace.config.fault_plan = Some(FaultPlan {
            seed: 3,
            events: vec![
                FaultEvent::DeviceCrash { device: 0, at: 0.1, recover_after: 0.2 },
                FaultEvent::TransientStall { device: 0, at: 0.3, duration: 0.1 },
            ],
        });
        trace.events.push(TraceEvent::Fault(FaultRecord {
            at: 0.1,
            fault: FaultEvent::DeviceCrash { device: 0, at: 0.1, recover_after: 0.2 },
        }));
        trace.events.push(TraceEvent::Decision(DecisionRecord {
            at: 0.15,
            tenant: 0,
            outcome: Outcome::Shed(ShedReason::NoHealthyDevice),
        }));
        let s = replay_summary(&trace, &ServeStats::default());
        assert!(s.contains("3 events (1 admits, 0 stats queries, 0 drains)"), "{s}");
        assert!(
            s.contains("2 scheduled event(s); 1 fault(s) fired, 1 degrade/shed decision(s)"),
            "{s}"
        );
        // A plain trace renders no fault-plan line at all.
        let plain = Trace::from_requests(
            HwConfig::alveo_u250(),
            FleetConfig::default(),
            vec![Request::full(0, ZooModel::B1, dataset("CO").unwrap(), 0.0)],
        );
        assert!(!replay_summary(&plain, &ServeStats::default()).contains("fault plan"));
    }

    #[test]
    fn replay_summary_names_tenant_qos_not_fault_plan() {
        use crate::config::HwConfig;
        use crate::graph::dataset;
        use crate::ir::ZooModel;
        use crate::serve::{
            DecisionRecord, FleetConfig, Outcome, PriorityClass, Request, ShedReason, Tenant,
            TenantConfig,
        };
        let mut trace = Trace::from_requests(
            HwConfig::alveo_u250(),
            FleetConfig::default(),
            vec![Request::full(0, ZooModel::B1, dataset("CO").unwrap(), 0.0)],
        );
        trace.config.tenants = Some(TenantConfig {
            tenants: vec![
                Tenant { id: 0, weight: 3.0, deadline_s: None, class: PriorityClass::Premium },
                Tenant {
                    id: 1,
                    weight: 1.0,
                    deadline_s: Some(0.05),
                    class: PriorityClass::BestEffort,
                },
            ],
        });
        trace.events.push(TraceEvent::Decision(DecisionRecord {
            at: 0.1,
            tenant: 1,
            outcome: Outcome::Shed(ShedReason::DeadlineMissed),
        }));
        let s = replay_summary(&trace, &ServeStats::default());
        assert!(
            s.contains(
                "tenant QoS: 2 configured tenant(s), total weight 4, \
                 1 degrade/shed decision(s) recorded"
            ),
            "{s}"
        );
        // QoS decisions must not masquerade as a fault-plan line.
        assert!(!s.contains("fault plan"), "{s}");
    }
}
