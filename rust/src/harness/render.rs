//! Minimal renderers for harness output: markdown tables plus the
//! serving-fleet summary block (the one place `ServeStats` is turned
//! into text, so every counter the coordinator tracks — including
//! coalesce and kernel re-map telemetry — is actually printed).

use crate::serve::ServeStats;

/// Render a markdown table.
pub fn markdown(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push('|');
    for h in headers {
        out.push_str(&format!(" {h} |"));
    }
    out.push('\n');
    out.push('|');
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push('|');
        for cell in row {
            out.push_str(&format!(" {cell} |"));
        }
        out.push('\n');
    }
    out
}

/// Format seconds as milliseconds with 3 significant decimals.
pub fn ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

/// Render the fleet counters of a serving run — every `ServeStats`
/// field, one aligned line each, including the coalesce and kernel
/// re-map counters that earlier revisions tracked but never printed.
pub fn serve_summary(stats: &ServeStats) -> String {
    let mut out = String::new();
    out.push_str(&format!("  completed         {}\n", stats.completed));
    out.push_str(&format!(
        "  cache hits        {} / {} ({} coalesced)\n",
        stats.cache_hits, stats.completed, stats.coalesced
    ));
    out.push_str(&format!("  kernel re-maps    {}\n", stats.remaps));
    out.push_str(&format!(
        "  latency p50/p99   {} ms / {} ms\n",
        ms(stats.p50),
        ms(stats.p99)
    ));
    out.push_str(&format!("  mean latency      {} ms\n", ms(stats.mean)));
    out.push_str(&format!(
        "  device busy       {:.3} s over {:.3} s makespan\n",
        stats.device_busy, stats.makespan
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_shape() {
        let t = markdown(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(t, "| a | b |\n|---|---|\n| 1 | 2 |\n");
    }

    #[test]
    fn ms_format() {
        assert_eq!(ms(0.0123456), "12.346");
    }

    #[test]
    fn serve_summary_prints_every_counter() {
        let stats = ServeStats {
            completed: 8,
            cache_hits: 7,
            coalesced: 3,
            remaps: 42,
            p50: 0.001,
            p99: 0.002,
            mean: 0.0015,
            device_busy: 0.5,
            makespan: 1.0,
        };
        let s = serve_summary(&stats);
        // The regression this guards: coalesce/remap counters tracked
        // but missing from the rendered output.
        assert!(s.contains("3 coalesced"), "{s}");
        assert!(s.contains("re-maps    42"), "{s}");
        assert!(s.contains("7 / 8"), "{s}");
        assert!(s.contains("1.000 ms / 2.000 ms"), "{s}");
    }
}
