//! Minimal markdown table renderer for harness output.

/// Render a markdown table.
pub fn markdown(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push('|');
    for h in headers {
        out.push_str(&format!(" {h} |"));
    }
    out.push('\n');
    out.push('|');
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push('|');
        for cell in row {
            out.push_str(&format!(" {cell} |"));
        }
        out.push('\n');
    }
    out
}

/// Format seconds as milliseconds with 3 significant decimals.
pub fn ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_shape() {
        let t = markdown(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(t, "| a | b |\n|---|---|\n| 1 | 2 |\n");
    }

    #[test]
    fn ms_format() {
        assert_eq!(ms(0.0123456), "12.346");
    }
}
