//! Shared support for the `rust/benches/*` binaries (plain `main`s —
//! the offline vendor set has no criterion; each bench times its harness
//! call and prints the regenerated table).
//!
//! Environment knobs:
//! * `GA_SCALE`    — divide dataset sizes by N (default 1 = paper scale),
//! * `GA_DATASETS` — comma list (default: all seven of Table 4).

use super::tables::Ctx;
use crate::graph::{dataset, Dataset, ALL_DATASETS};
use std::time::Instant;

/// The `GA_SCALE` dataset divisor (default 1 = paper scale).
pub fn scale_from_env() -> u64 {
    std::env::var("GA_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// The `GA_DATASETS` selection (default: all seven of Table 4).
pub fn datasets_from_env() -> Vec<Dataset> {
    match std::env::var("GA_DATASETS") {
        Ok(list) if !list.is_empty() && list != "all" => list
            .split(',')
            .filter_map(|k| dataset(k.trim()))
            .collect(),
        _ => ALL_DATASETS.to_vec(),
    }
}

/// Run one named bench body, print its output and wall time.
pub fn run_bench(name: &str, body: impl FnOnce(&mut Ctx, &[Dataset]) -> String) {
    let scale = scale_from_env();
    let datasets = datasets_from_env();
    let mut ctx = Ctx::new(scale);
    eprintln!(
        "[{name}] scale=1/{scale}, datasets={:?}",
        datasets.iter().map(|d| d.key).collect::<Vec<_>>()
    );
    let t0 = Instant::now();
    let table = body(&mut ctx, &datasets);
    let secs = t0.elapsed().as_secs_f64();
    println!("# {name} (regenerated in {secs:.2} s, scale 1/{scale})\n");
    println!("{table}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_defaults() {
        // (Do not set env in tests — just exercise the default paths.)
        assert!(scale_from_env() >= 1);
        assert_eq!(datasets_from_env().len(), 7);
    }
}
