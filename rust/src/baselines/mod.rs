//! Analytic models of the systems GraphAGILE is compared against in the
//! paper's evaluation (Sec. 8.3–8.4): PyG / DGL on the CPU-only and
//! CPU-GPU platforms of Table 6, and the HyGCN / AWB-GCN / BoostGCN
//! accelerators of Table 3.
//!
//! These are roofline-style models parameterized by each platform's
//! published constants (peak flops, memory bandwidth, on-chip memory)
//! plus a small number of architecture factors (framework overhead,
//! message materialization, hybrid-pipeline imbalance, sparsity
//! exploitation) taken from the respective papers. The goal — per
//! DESIGN.md "Substitutions" — is to reproduce the *shape* of Figs.
//! 17–18 and Table 10 (who wins, by roughly what factor), not absolute
//! milliseconds measured on hardware we do not have.

pub mod accel;
pub mod roofline;

pub use accel::{awb_gcn_loh, boostgcn_loh, hygcn_loh};
pub use roofline::{framework_e2e, Framework, FrameworkResult, Processor};
