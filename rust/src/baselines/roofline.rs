//! Roofline models of PyG and DGL on the CPU-only and CPU-GPU platforms
//! (paper Table 6, Figs. 17–18).
//!
//! Per computation layer, time = max(compute roofline, memory roofline)
//! plus a per-kernel framework overhead; the frameworks execute the IR
//! *as written* (no computation-order optimization, no fusion — the
//! paper's Sec. 8.3 notes these could apply to CPU/GPU but are not in
//! the released frameworks' inference paths).
//!
//! The architecture factors below are the published/first-order
//! characteristics of each framework:
//! * **PyG** materializes per-edge messages (gather -> message tensor ->
//!   scatter): sparse traffic ~ 3 |E| f words and a matching memory
//!   footprint (the source of its OOMs on RE/YE/AP, Fig. 18);
//! * **DGL** uses fused SpMM (no message tensor): traffic ~ |E| edges +
//!   2 |V| f words;
//! * CPUs sustain a fraction of peak on irregular kernels (cache-miss
//!   bound); GPUs add a fixed launch latency per kernel.

use crate::config::{Platform, CPU_RYZEN_3990X, GPU_RTX3090};
use crate::ir::{LayerType, ModelIr};

/// Which framework.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framework {
    PyG,
    Dgl,
}

/// Which processor of the baseline platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Processor {
    Cpu,
    Gpu,
}

/// Model outcome: either a latency or an out-of-memory failure.
#[derive(Clone, Copy, Debug)]
pub enum FrameworkResult {
    Seconds(f64),
    Oom,
}

impl FrameworkResult {
    pub fn seconds(&self) -> Option<f64> {
        match self {
            FrameworkResult::Seconds(s) => Some(*s),
            FrameworkResult::Oom => None,
        }
    }
}

struct Factors {
    /// Sustained fraction of peak flops on dense kernels.
    eff_dense: f64,
    /// Sustained fraction of peak flops on sparse kernels.
    eff_sparse: f64,
    /// Fixed overhead per launched kernel (s).
    kernel_overhead: f64,
    /// One-time runtime startup / dispatch overhead (s).
    startup: f64,
    /// Per-edge graph construction / format conversion overhead (s) —
    /// the framework's preprocessing the paper includes in E2E.
    prep_per_edge: f64,
    /// Host->device transfer bandwidth counted in E2E (0 = none).
    h2d_bw: f64,
    /// Effective memory bandwidth fraction on irregular access
    /// (cache-line-granular gathers of 4-byte features).
    bw_irregular: f64,
    /// Device memory capacity for the OOM rule (bytes).
    mem_capacity: f64,
}

fn factors(fw: Framework, proc: Processor) -> (Platform, Factors) {
    match (proc, fw) {
        (Processor::Cpu, Framework::PyG) => (
            CPU_RYZEN_3990X,
            Factors {
                eff_dense: 0.35,
                eff_sparse: 0.004,
                kernel_overhead: 30e-6,
                startup: 0.3e-3,
                prep_per_edge: 20e-9,
                h2d_bw: 0.0,
                bw_irregular: 0.12,
                mem_capacity: 256e9,
            },
        ),
        (Processor::Cpu, Framework::Dgl) => (
            CPU_RYZEN_3990X,
            Factors {
                eff_dense: 0.35,
                eff_sparse: 0.006,
                kernel_overhead: 30e-6,
                startup: 0.3e-3,
                prep_per_edge: 15e-9,
                h2d_bw: 0.0,
                bw_irregular: 0.15,
                mem_capacity: 256e9,
            },
        ),
        (Processor::Gpu, Framework::PyG) => (
            GPU_RTX3090,
            Factors {
                eff_dense: 0.45,
                eff_sparse: 0.03,
                kernel_overhead: 20e-6,
                startup: 2.0e-3,
                prep_per_edge: 10e-9,
                h2d_bw: 16e9,
                bw_irregular: 0.30,
                mem_capacity: 24e9,
            },
        ),
        (Processor::Gpu, Framework::Dgl) => (
            GPU_RTX3090,
            Factors {
                eff_dense: 0.45,
                eff_sparse: 0.06,
                kernel_overhead: 20e-6,
                startup: 2.0e-3,
                prep_per_edge: 8e-9,
                h2d_bw: 16e9,
                bw_irregular: 0.45,
                mem_capacity: 24e9,
            },
        ),
    }
}

/// Kernels a framework launches for one IR layer (drives GPU overhead).
fn kernels_of(lt: LayerType) -> u64 {
    match lt {
        LayerType::Aggregate => 3,   // gather + message + scatter-reduce
        LayerType::Linear => 1,      // cuBLAS/MKL GEMM
        LayerType::VectorInner => 2, // gather pairs + dot
        LayerType::VectorAdd => 1,
        LayerType::Activation => 1,
        LayerType::BatchNorm => 1,
    }
}

/// End-to-end model latency for a framework on the *unoptimized* IR.
/// Includes the framework's preprocessing/launch overheads (the paper's
/// E2E metric for CPU/GPU platforms).
pub fn framework_e2e(ir: &ModelIr, fw: Framework, proc: Processor) -> FrameworkResult {
    let (plat, f) = factors(fw, proc);
    // OOM rule. PyG's MessagePassing materializes a per-edge message
    // tensor at the aggregation width (GCNConv applies the linear first,
    // so the width is min(f_in, f_out) of the surrounding transform),
    // holding ~3 copies (message, normalized message, scatter output).
    // Its COO preprocessing (coalesce/sort + norm) additionally peaks at
    // a large per-edge working set on the host — the empirical blowup
    // that makes Amazon-Products (264M edges) exceed the 3990x's 256 GB
    // while Reddit (116M) still fits, matching Fig. 18's OOM pattern.
    // DGL's fused SpMM keeps only feature-matrix-sized buffers.
    let h_msg = ir
        .layers
        .iter()
        .filter(|l| l.ltype == LayerType::Aggregate)
        .map(|l| {
            ir.layers
                .iter()
                .filter(|m| m.ltype == LayerType::Linear)
                .map(|m| m.f_out.min(l.f_in))
                .max()
                .unwrap_or(l.f_in)
        })
        .max()
        .unwrap_or(1);
    let base_bytes = ir.graph.input_bytes() as f64;
    let footprint = match (fw, proc) {
        (Framework::PyG, Processor::Cpu) => {
            base_bytes
                + 3.0 * (ir.graph.n_edges * h_msg * 4) as f64
                + ir.graph.n_edges as f64 * 600.0 // host preprocessing peak
        }
        (Framework::PyG, Processor::Gpu) => {
            base_bytes
                + 3.0 * (ir.graph.n_edges * h_msg * 4) as f64
                + ir.graph.n_edges as f64 * 100.0 // device edge working set
        }
        (Framework::Dgl, _) => {
            base_bytes
                + (ir.graph.n_vertices
                    * ir.layers.iter().map(|l| l.f_in.max(l.f_out)).max().unwrap_or(1)
                    * 4) as f64
                    * 3.0
        }
    };
    if footprint > f.mem_capacity {
        return FrameworkResult::Oom;
    }
    // Framework preprocessing the paper's E2E includes: runtime startup,
    // graph construction (~per-edge), and the host->device input copy.
    let mut t = f.startup + ir.graph.n_edges as f64 * f.prep_per_edge;
    if f.h2d_bw > 0.0 {
        t += base_bytes / f.h2d_bw;
    }
    for l in &ir.layers {
        let flops = l.complexity() as f64;
        let (eff, bytes) = match l.ltype {
            LayerType::Aggregate | LayerType::VectorInner => {
                // Both frameworks gather an f-wide source row per edge
                // (cache-line-granular random access); PyG additionally
                // materializes + scatters the message tensor.
                let gather = (l.ne * l.f_in * 4) as f64;
                let traffic = match fw {
                    Framework::PyG => 3.0 * gather,
                    Framework::Dgl => gather + (l.nv * l.f_in * 8) as f64,
                };
                (f.eff_sparse, traffic / f.bw_irregular)
            }
            LayerType::Linear => {
                let traffic = ((l.f_in + l.f_out) * l.nv * 4) as f64;
                (f.eff_dense, traffic)
            }
            _ => {
                let traffic = 2.0 * (l.nv * l.f_in * 4) as f64;
                (f.eff_dense, traffic)
            }
        };
        let t_compute = flops / (plat.peak_flops * eff);
        let t_memory = bytes / plat.mem_bw;
        t += t_compute.max(t_memory) + kernels_of(l.ltype) as f64 * f.kernel_overhead;
    }
    FrameworkResult::Seconds(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{dataset, Dataset};
    use crate::ir::ZooModel;

    fn e2e(m: ZooModel, d: Dataset, fw: Framework, p: Processor) -> FrameworkResult {
        framework_e2e(&m.build(d.meta()), fw, p)
    }

    #[test]
    fn pyg_oom_matches_fig18() {
        // Paper: PyG-GPU OOM on RE, YE, AP; fine on CI/CO/PU/FL. Our
        // footprint model reproduces RE and AP (the giant-edge graphs);
        // YE (7M edges) fits 24 GB under any first-order accounting —
        // recorded as a known deviation in EXPERIMENTS.md.
        for key in ["RE", "AP"] {
            let r = e2e(ZooModel::B2, dataset(key).unwrap(), Framework::PyG, Processor::Gpu);
            assert!(matches!(r, FrameworkResult::Oom), "{key} should OOM");
        }
        for key in ["CI", "CO", "PU", "FL"] {
            let r = e2e(ZooModel::B2, dataset(key).unwrap(), Framework::PyG, Processor::Gpu);
            assert!(r.seconds().is_some(), "{key} should fit");
        }
        // PyG-CPU OOM on AP but not RE (as in Fig. 18).
        let r = e2e(ZooModel::B1, dataset("AP").unwrap(), Framework::PyG, Processor::Cpu);
        assert!(matches!(r, FrameworkResult::Oom));
        let r = e2e(ZooModel::B1, dataset("RE").unwrap(), Framework::PyG, Processor::Cpu);
        assert!(r.seconds().is_some());
    }

    #[test]
    fn dgl_never_ooms_on_benchmarks() {
        for d in crate::graph::ALL_DATASETS {
            for p in [Processor::Cpu, Processor::Gpu] {
                let r = e2e(ZooModel::B2, d, Framework::Dgl, p);
                assert!(r.seconds().is_some(), "{} {p:?}", d.key);
            }
        }
    }

    #[test]
    fn gpu_beats_cpu() {
        for fw in [Framework::PyG, Framework::Dgl] {
            let c = e2e(ZooModel::B2, dataset("FL").unwrap(), fw, Processor::Cpu)
                .seconds()
                .unwrap();
            let g = e2e(ZooModel::B2, dataset("FL").unwrap(), fw, Processor::Gpu)
                .seconds()
                .unwrap();
            assert!(g < c, "{fw:?}: gpu {g} >= cpu {c}");
        }
    }

    #[test]
    fn dgl_faster_than_pyg_on_sparse_heavy() {
        let p = e2e(ZooModel::B1, dataset("RE").unwrap(), Framework::PyG, Processor::Cpu)
            .seconds()
            .unwrap();
        let d = e2e(ZooModel::B1, dataset("RE").unwrap(), Framework::Dgl, Processor::Cpu)
            .seconds()
            .unwrap();
        assert!(d < p, "dgl {d} >= pyg {p}");
    }

    #[test]
    fn latency_scales_with_graph() {
        let small = e2e(ZooModel::B1, dataset("CO").unwrap(), Framework::Dgl, Processor::Gpu)
            .seconds()
            .unwrap();
        let big = e2e(ZooModel::B1, dataset("FL").unwrap(), Framework::Dgl, Processor::Gpu)
            .seconds()
            .unwrap();
        assert!(big > small);
    }
}
