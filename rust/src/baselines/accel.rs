//! Analytic LoH models of the accelerator baselines in Table 10:
//! HyGCN (ASIC), AWB-GCN (Stratix 10 SX), BoostGCN (Stratix 10 GX),
//! evaluated at the paper's matched workload (model b2 = 2-layer GCN,
//! hidden 128). Each model uses the platform constants of Tables 3/6
//! plus the architecture characteristics the respective papers report.

use crate::config::{Platform, ACCEL_AWB_GCN, ACCEL_BOOSTGCN, ACCEL_HYGCN};
use crate::ir::{LayerType, ModelIr};

/// Per-layer flop/traffic demand of a GCN executed *without* GraphAGILE's
/// compiler optimizations (the baselines schedule layers as written, but
/// each applies its own dataflow).
struct Demand {
    agg_flops: f64,
    comb_flops: f64,
    edge_bytes: f64,
    feat_bytes: f64,
}

fn demand(ir: &ModelIr) -> Demand {
    let mut d = Demand { agg_flops: 0.0, comb_flops: 0.0, edge_bytes: 0.0, feat_bytes: 0.0 };
    for l in &ir.layers {
        match l.ltype {
            LayerType::Aggregate | LayerType::VectorInner => {
                d.agg_flops += l.complexity() as f64;
                d.edge_bytes += (l.ne * 12) as f64;
                d.feat_bytes += 2.0 * (l.nv * l.f_in * 4) as f64;
            }
            LayerType::Linear => {
                d.comb_flops += l.complexity() as f64;
                d.feat_bytes += ((l.f_in + l.f_out) * l.nv * 4) as f64;
            }
            _ => {
                d.comb_flops += l.complexity() as f64;
                d.feat_bytes += 2.0 * (l.nv * l.f_in * 4) as f64;
            }
        }
    }
    d
}

fn pipeline_time(plat: &Platform, d: &Demand, split: f64, imbalance: f64,
                 agg_eff: f64, comb_eff: f64, reuse: f64) -> f64 {
    // Hybrid architectures dedicate `split` of peak to aggregation and
    // the rest to combination; the stages pipeline but load imbalance
    // leaves bubbles (the inefficiency GraphAGILE's unified ACK removes).
    let t_agg = d.agg_flops / (plat.peak_flops * split * agg_eff);
    let t_comb = d.comb_flops / (plat.peak_flops * (1.0 - split) * comb_eff);
    let t_mem = (d.edge_bytes + d.feat_bytes * reuse) / plat.mem_bw;
    t_agg.max(t_comb).max(t_mem) * imbalance
}

/// HyGCN: hybrid aggregation (SIMD) + combination (systolic) engines with
/// inter-engine coordination; window-sparsity elimination reduces edge
/// traffic but the hybrid pipeline suffers imbalance (paper Sec. 8.4).
pub fn hygcn_loh(ir: &ModelIr) -> f64 {
    let d = demand(ir);
    pipeline_time(&ACCEL_HYGCN, &d, 0.4, 1.9, 0.5, 0.75, 1.0)
}

/// AWB-GCN: unified SpMM engine with runtime workload rebalancing and
/// feature-sparsity exploitation (effective flops scaled by the nonzero
/// density of intermediate features, ~0.45 on the benchmark graphs).
pub fn awb_gcn_loh(ir: &ModelIr) -> f64 {
    let d = demand(ir);
    let density = 0.45;
    let flops = (d.agg_flops + d.comb_flops) * density;
    let t_compute = flops / (ACCEL_AWB_GCN.peak_flops * 0.72);
    let t_mem = (d.edge_bytes * density + d.feat_bytes) / ACCEL_AWB_GCN.mem_bw;
    t_compute.max(t_mem) * 1.08
}

/// BoostGCN: partition-centric feature-update + aggregation pipelines;
/// no overlay ISA (per-design bitstream) but the same Stratix-class
/// bandwidth; hybrid imbalance is milder than HyGCN's.
pub fn boostgcn_loh(ir: &ModelIr) -> f64 {
    let d = demand(ir);
    pipeline_time(&ACCEL_BOOSTGCN, &d, 0.5, 1.45, 0.5, 0.8, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;
    use crate::ir::ZooModel;

    fn b2(key: &str) -> ModelIr {
        ZooModel::B2.build(dataset(key).unwrap().meta())
    }

    #[test]
    fn hygcn_reddit_order_of_paper() {
        // Paper Table 10: HyGCN on RE = 289 ms. Same order of magnitude.
        let ms = hygcn_loh(&b2("RE")) * 1e3;
        assert!((80.0..900.0).contains(&ms), "HyGCN RE {ms} ms");
    }

    #[test]
    fn awb_gcn_fastest_on_reddit() {
        // Paper: AWB-GCN (49.7 ms) beats everyone on RE thanks to 2.2x
        // peak and sparsity exploitation.
        let awb = awb_gcn_loh(&b2("RE"));
        let boost = boostgcn_loh(&b2("RE"));
        let hygcn = hygcn_loh(&b2("RE"));
        assert!(awb < boost && awb < hygcn, "awb {awb} boost {boost} hygcn {hygcn}");
        let ms = awb * 1e3;
        assert!((15.0..200.0).contains(&ms), "AWB RE {ms} ms");
    }

    #[test]
    fn boostgcn_flickr_order_of_paper() {
        // Paper Table 10: BoostGCN on FL = 20.1 ms.
        let ms = boostgcn_loh(&b2("FL")) * 1e3;
        assert!((5.0..80.0).contains(&ms), "BoostGCN FL {ms} ms");
    }

    #[test]
    fn all_models_scale_with_graph() {
        for f in [hygcn_loh, awb_gcn_loh, boostgcn_loh] {
            assert!(f(&b2("FL")) < f(&b2("RE")));
            assert!(f(&b2("YE")) < f(&b2("AP")));
        }
    }
}
