//! Multi-tenant QoS serving benchmark: the same deterministic workloads
//! served tenant-blind (FIFO) and under a weighted-fair tenant config.
//! Written to `BENCH_qos.json` so the isolation trajectory is recorded
//! across commits; everything runs on the virtual clock, so the numbers
//! are bit-identical between runs.
//!
//! Two scenarios:
//!   * `mixed` — a best-effort flood with premium requests interleaved:
//!     FIFO makes the premium tenant queue behind the flood; QoS gives
//!     it strict priority and gap backfill.
//!   * `saturate` — three standard tenants (weights 4/2/1) with equal
//!     backlogged demand: SFQ pacing must hand out device time in
//!     proportion to weight while every tenant stays backlogged.
//!
//! Strict gates (`GA_BENCH_STRICT=1`):
//!   * premium p99 under QoS stays within 0.5x the FIFO baseline,
//!   * every tenant's throughput share in the backlogged window stays
//!     within 0.8x of its weight share (no starvation).
//!
//! Knobs: `GA_REQUESTS` (default 400).

use graphagile::config::HwConfig;
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::serve::{
    percentile, Coordinator, FleetConfig, PriorityClass, Request, ServeStats, Tenant,
    TenantConfig,
};
use graphagile::util::Rng;

const DEVICES: usize = 2;
const SPACING_S: f64 = 1e-4;

const PREMIUM: u32 = 0;
const FLOOD: u32 = 1;

/// A best-effort flood with one premium request in every 8 slots.
fn mixed_workload(n: usize, seed: u64) -> Vec<Request> {
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B6, ZooModel::B7];
    let graphs = [dataset("CI").unwrap(), dataset("CO").unwrap(), dataset("PU").unwrap()];
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let tenant = if i % 8 == 3 { PREMIUM } else { FLOOD };
            Request::full(
                tenant,
                models[rng.below(4) as usize],
                graphs[rng.below(3) as usize],
                i as f64 * SPACING_S,
            )
        })
        .collect()
}

fn mixed_tenants() -> TenantConfig {
    TenantConfig {
        tenants: vec![
            Tenant { id: PREMIUM, weight: 8.0, deadline_s: None, class: PriorityClass::Premium },
            Tenant { id: FLOOD, weight: 1.0, deadline_s: None, class: PriorityClass::BestEffort },
        ],
    }
}

/// Three standard tenants with identical per-slot demand — only their
/// weights differ, so realized throughput shares isolate the scheduler.
const SAT_TENANTS: [(u32, f64); 3] = [(10, 4.0), (11, 2.0), (12, 1.0)];

fn saturate_workload(n: usize, seed: u64) -> Vec<Request> {
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B6, ZooModel::B7];
    let graphs = [dataset("CI").unwrap(), dataset("CO").unwrap(), dataset("PU").unwrap()];
    let mut rng = Rng::new(seed);
    let slots = n / SAT_TENANTS.len();
    let mut reqs = Vec::new();
    for i in 0..slots {
        // Every tenant submits the same (model, graph) in the same slot:
        // identical demand profiles, distinct arrival instants.
        let model = models[rng.below(4) as usize];
        let graph = graphs[rng.below(3) as usize];
        for (k, &(tenant, _)) in SAT_TENANTS.iter().enumerate() {
            let arrival = (i * SAT_TENANTS.len() + k) as f64 * (SPACING_S / 3.0);
            reqs.push(Request::full(tenant, model, graph, arrival));
        }
    }
    reqs
}

fn saturate_tenants() -> TenantConfig {
    TenantConfig {
        tenants: SAT_TENANTS
            .iter()
            .map(|&(id, weight)| Tenant {
                id,
                weight,
                deadline_s: None,
                class: PriorityClass::Standard,
            })
            .collect(),
    }
}

fn serve(reqs: &[Request], tenants: Option<TenantConfig>) -> (Coordinator, ServeStats) {
    // Coalescing and micro-batching are off in both runs so the FIFO
    // baseline and the QoS run schedule the same per-request work.
    let cfg = FleetConfig {
        n_devices: DEVICES,
        coalesce: false,
        microbatch: false,
        ..FleetConfig::default()
    };
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
    if let Some(t) = tenants {
        c.set_tenants(t);
    }
    let stats = c.run(reqs.to_vec());
    (c, stats)
}

/// Nearest-rank latency percentile of one tenant's served requests.
fn tenant_lat(c: &Coordinator, tenant: u32, p: f64) -> f64 {
    let mut lats: Vec<f64> = c
        .responses
        .iter()
        .filter(|r| r.tenant == tenant && !r.outcome.is_shed())
        .map(|r| r.latency)
        .collect();
    lats.sort_by(f64::total_cmp);
    percentile(&lats, p)
}

fn shed_of(c: &Coordinator, tenant: u32) -> u64 {
    c.responses.iter().filter(|r| r.tenant == tenant && r.outcome.is_shed()).count() as u64
}

/// Per-tenant executed device-seconds within the earliest `frac` of
/// completions — the backlogged window where throughput shares are
/// meaningful (over a fully drained run every tenant completes all of
/// its demand, so shares trivially converge to demand shares).
fn window_shares(reqs: &[Request], c: &Coordinator, frac: f64) -> Vec<(u32, f64)> {
    // `reqs` is strictly arrival-sorted, which is exactly the admission
    // (and response) order, so zip pairs each response with its request.
    let mut rows: Vec<(f64, u32, f64)> = reqs
        .iter()
        .zip(&c.responses)
        .filter(|(_, r)| !r.outcome.is_shed())
        .map(|(q, r)| (q.arrival + r.latency, r.tenant, r.t_exec))
        .collect();
    rows.sort_by(|a, b| a.0.total_cmp(&b.0));
    let k = ((rows.len() as f64 * frac) as usize).clamp(1, rows.len());
    let mut busy: Vec<(u32, f64)> = Vec::new();
    for &(_, tenant, exec) in &rows[..k] {
        match busy.iter_mut().find(|(id, _)| *id == tenant) {
            Some((_, b)) => *b += exec,
            None => busy.push((tenant, exec)),
        }
    }
    busy.sort_by_key(|&(id, _)| id);
    let total: f64 = busy.iter().map(|&(_, b)| b).sum();
    busy.into_iter().map(|(id, b)| (id, if total > 0.0 { b / total } else { 0.0 })).collect()
}

fn mixed_row(name: &str, c: &Coordinator, s: &ServeStats) -> String {
    format!(
        "    {{\"scenario\": \"{name}\", \"premium_p50_ms\": {:.4}, \
         \"premium_p99_ms\": {:.4}, \"flood_p99_ms\": {:.4}, \"completed\": {}, \
         \"shed\": {}, \"degraded\": {}, \"preemptions\": {}, \"makespan_s\": {:.6}}}",
        tenant_lat(c, PREMIUM, 0.50) * 1e3,
        tenant_lat(c, PREMIUM, 0.99) * 1e3,
        tenant_lat(c, FLOOD, 0.99) * 1e3,
        s.completed,
        s.shed,
        s.degraded,
        c.qos_preemptions(),
        s.makespan,
    )
}

fn main() {
    let n: usize = std::env::var("GA_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let strict = std::env::var("GA_BENCH_STRICT").ok().as_deref() == Some("1");

    let mixed = mixed_workload(n, 17);
    let (fifo_c, fifo_s) = serve(&mixed, None);
    let (qos_c, qos_s) = serve(&mixed, Some(mixed_tenants()));

    let sat = saturate_workload(n, 29);
    let (sat_c, sat_s) = serve(&sat, Some(saturate_tenants()));
    let shares = window_shares(&sat, &sat_c, 0.4);
    let total_w: f64 = SAT_TENANTS.iter().map(|&(_, w)| w).sum();

    let fifo_p99 = tenant_lat(&fifo_c, PREMIUM, 0.99);
    let qos_p99 = tenant_lat(&qos_c, PREMIUM, 0.99);
    let p99_ratio = if fifo_p99 > 0.0 { qos_p99 / fifo_p99 } else { f64::INFINITY };

    println!(
        "{:>12} {:>14} {:>14} {:>12} {:>6} {:>11}",
        "scenario", "prem p50 (ms)", "prem p99 (ms)", "flood p99", "shed", "preemptions"
    );
    for (name, c, s) in [("fifo", &fifo_c, &fifo_s), ("qos", &qos_c, &qos_s)] {
        println!(
            "{:>12} {:>14.3} {:>14.3} {:>12.3} {:>6} {:>11}",
            name,
            tenant_lat(c, PREMIUM, 0.50) * 1e3,
            tenant_lat(c, PREMIUM, 0.99) * 1e3,
            tenant_lat(c, FLOOD, 0.99) * 1e3,
            s.shed,
            c.qos_preemptions(),
        );
    }
    println!("saturate shares (first 40% of completions):");
    let mut worst_ratio = f64::INFINITY;
    for &(id, share) in &shares {
        let weight = SAT_TENANTS.iter().find(|&&(t, _)| t == id).map_or(1.0, |&(_, w)| w);
        let weight_share = weight / total_w;
        worst_ratio = worst_ratio.min(share / weight_share);
        println!(
            "  tenant {id}: share {:.3} vs weight share {:.3} ({:.2}x)",
            share,
            weight_share,
            share / weight_share
        );
    }

    let share_rows: Vec<String> = shares
        .iter()
        .map(|&(id, share)| {
            let weight =
                SAT_TENANTS.iter().find(|&&(t, _)| t == id).map_or(1.0, |&(_, w)| w);
            format!(
                "      {{\"tenant\": {id}, \"weight\": {weight}, \"share\": {share:.6}, \
                 \"weight_share\": {:.6}}}",
                weight / total_w
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"qos_serve\",\n  \"requests\": {n},\n  \"devices\": {DEVICES},\n  \
         \"scenarios\": [\n{},\n    {{\"scenario\": \"saturate\", \"completed\": {}, \
         \"shed\": {}, \"makespan_s\": {:.6}, \"shares\": [\n{}\n    ]}}\n  ],\n  \
         \"gates\": {{\"premium_p99_ratio\": {p99_ratio:.6}, \
         \"worst_share_ratio\": {worst_ratio:.6}}}\n}}\n",
        [mixed_row("fifo_mixed", &fifo_c, &fifo_s), mixed_row("qos_mixed", &qos_c, &qos_s)]
            .join(",\n"),
        sat_s.completed,
        sat_s.shed,
        sat_s.makespan,
        share_rows.join(",\n"),
    );
    std::fs::write("BENCH_qos.json", &json).expect("write BENCH_qos.json");
    eprintln!("wrote BENCH_qos.json ({n} requests, {DEVICES} devices)");

    // Accounting invariants hold strict or not.
    assert_eq!(fifo_s.shed, 0, "tenant-blind serving must not shed");
    assert_eq!(shed_of(&qos_c, PREMIUM), 0, "premium traffic must never be shed");
    assert_eq!(
        qos_s.completed + qos_s.shed,
        n as u64,
        "every request must end completed, degraded, or shed"
    );
    assert_eq!(sat_s.shed, 0, "deadline-free standard tenants must not shed");
    assert!(fifo_s.tenants.is_empty(), "FIFO baseline must stay tenant-blind");
    assert!(!qos_s.tenants.is_empty(), "QoS run must report per-tenant families");

    if strict {
        assert!(
            qos_p99 <= 0.5 * fifo_p99,
            "STRICT: premium p99 under QoS ({:.3} ms) exceeds 0.5 x the FIFO \
             baseline ({:.3} ms)",
            qos_p99 * 1e3,
            fifo_p99 * 1e3,
        );
        assert!(
            worst_ratio >= 0.8,
            "STRICT: worst tenant throughput share is {worst_ratio:.3}x its weight \
             share (floor 0.8x — starvation)",
        );
        eprintln!(
            "STRICT gates passed: premium p99 {:.3} ms <= 0.5 x FIFO {:.3} ms, \
             worst share ratio {worst_ratio:.2}x >= 0.8x",
            qos_p99 * 1e3,
            fifo_p99 * 1e3,
        );
    }
}
