//! Micro-benchmarks of the L3 hot paths (the §Perf targets in
//! EXPERIMENTS.md): R-MAT edge generation, Fiber-Shard histogramming,
//! kernel mapping, binary encode, whole-program simulation rates, and
//! the tile executor itself — through both [`TileBackend`]
//! implementations (naive [`ReferenceBackend`] vs optimized
//! [`RustBackend`]) at both precisions (f32 and calibrated int8), with
//! a self-check asserting the reference and optimized outputs agree.

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::exec::{
    FunctionalExecutor, ReferenceBackend, RustBackend, TileBackend, WeightStore,
};
use graphagile::graph::{
    dataset, rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph, RmatParams, TileCounts,
};
use graphagile::ir::ZooModel;
use graphagile::quant::{calibrate, CalibrationProfile};
use graphagile::sim::simulate;
use graphagile::util::Rng;
use std::time::Instant;

fn rate(name: &str, items: f64, unit: &str, f: impl FnOnce()) {
    let t0 = Instant::now();
    f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:34} {secs:9.3} s   {:10.2} M{unit}/s", items / secs / 1e6);
}

fn main() {
    println!("# hotpath_micro\n");
    let d = dataset("FL").unwrap();
    let n1 = 16384u64;

    // 1. Synthetic edge generation (workload setup, not T_LoC).
    let m = 5_000_000usize;
    let mut rng = Rng::new(1);
    let mut edges = (Vec::new(), Vec::new());
    rate("rmat_generate (5M edges)", m as f64, "edge", || {
        edges = RmatParams::default().sample_edges(&mut rng, d.n_vertices, m);
    });

    // 2. Fiber-Shard histogram (the dominant T_LoC term).
    let (src, dst) = &edges;
    let mut tc = None;
    rate("tile_histogram (5M edges)", m as f64, "edge", || {
        tc = Some(TileCounts::from_edges(src, dst, d.n_vertices, n1));
    });

    // 3. Kernel mapping + codegen (b5 = deepest model).
    let hw = HwConfig::alveo_u250();
    let tiles = d.tile_counts(n1);
    let ir = ZooModel::B5.build(d.meta());
    let mut exe = None;
    let t0 = Instant::now();
    for _ in 0..10 {
        exe = Some(compile(&ir, &tiles, &hw, CompileOptions::default()));
    }
    let secs = t0.elapsed().as_secs_f64() / 10.0;
    let exe = exe.unwrap();
    let n_instr = exe.program.total_instrs();
    println!(
        "{:34} {secs:9.5} s   {:10.2} Minstr/s  ({n_instr} instrs)",
        "compile b5/FL (avg of 10)",
        n_instr as f64 / secs / 1e6
    );

    // 4. Binary encode/decode round trip.
    let t0 = Instant::now();
    let bytes = exe.program.to_bytes();
    let enc = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let back = graphagile::isa::Program::from_bytes(&bytes).unwrap();
    let dec = t0.elapsed().as_secs_f64();
    assert_eq!(back.total_instrs(), n_instr);
    println!(
        "{:34} enc {enc:.5} s / dec {dec:.5} s ({:.1} MB)",
        "binary roundtrip b5/FL",
        bytes.len() as f64 / 1e6
    );

    // 5. Simulation rate.
    let t0 = Instant::now();
    let runs = 10;
    let mut cycles = 0;
    for _ in 0..runs {
        cycles = simulate(&exe.program, &hw).cycles;
    }
    let secs = t0.elapsed().as_secs_f64() / runs as f64;
    println!(
        "{:34} {secs:9.5} s   {:10.2} Minstr/s  ({cycles} cycles simulated)",
        "simulate b5/FL (avg of 10)",
        n_instr as f64 / secs / 1e6
    );

    // 6. Tile executor: both backends, both precisions. The naive
    // ReferenceBackend is the per-call-allocating baseline; the
    // optimized RustBackend is timed steady-state (warm arena, packed
    // weights). Quantized tiles run the same int8 kernels under either
    // backend, so the int8 rows measure the surrounding executor too.
    println!();
    let meta = GraphMeta::new("hot", 2048, 16_384, 64, 8);
    let g = rmat_edges(meta, Default::default(), 31).gcn_normalized();
    let hw = HwConfig::functional_tiles();
    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    let pg = PartitionedGraph::build(&g, cfg);
    let x = g.random_features(5);
    let visits = g.m() as f64;
    for quantized in [false, true] {
        let ir = ZooModel::B5.build(g.meta.clone());
        let mut exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        if quantized {
            let cal = calibrate(&exe.ir, &store, &CalibrationProfile::exact(&g, &x));
            exe.program.scales = Some(cal.table);
        }
        let label = if quantized { "int8" } else { "f32" };
        let mut naive_out = Vec::new();
        rate(&format!("tile_exec b5 naive/{label}"), visits, "edge-visit", || {
            naive_out = run_backend(ReferenceBackend, &exe, &pg, &store, &x);
        });
        let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let warm = fx.run(&x); // pack + warm the arena
        let mut opt_out = Vec::new();
        rate(&format!("tile_exec b5 opt/{label} (warm)"), visits, "edge-visit", || {
            opt_out = fx.run(&x);
        });
        assert_eq!(warm, opt_out, "{label}: warm run changed numerics");
        if quantized {
            assert!(fx.quant_visits > 0, "scaled program never took the int8 path");
        }
        // Self-check: the two backends compute the same function (the
        // optimized side reorders f32 reductions, hence the epsilon).
        let scale = naive_out.iter().fold(1f32, |m, v| m.max(v.abs()));
        for (i, (a, b)) in opt_out.iter().zip(&naive_out).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * scale,
                "{label}: backends disagree at [{i}]: {a} vs {b}"
            );
        }
    }
}

/// One cold executor pass through `backend` (the generic bound is the
/// point: this bench covers the [`TileBackend`] trait object the same
/// way the serving fleet drives it).
fn run_backend<B: TileBackend>(
    backend: B,
    exe: &graphagile::compiler::Executable,
    pg: &PartitionedGraph,
    store: &WeightStore,
    x: &[f32],
) -> Vec<f32> {
    FunctionalExecutor::new(exe, pg, store, backend).run(x)
}
