//! Micro-benchmarks of the L3 hot paths (the §Perf targets in
//! EXPERIMENTS.md): R-MAT edge generation, Fiber-Shard histogramming,
//! kernel mapping, binary encode, and whole-program simulation rates.

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::{dataset, RmatParams, TileCounts};
use graphagile::ir::ZooModel;
use graphagile::sim::simulate;
use graphagile::util::Rng;
use std::time::Instant;

fn rate(name: &str, items: f64, unit: &str, f: impl FnOnce()) {
    let t0 = Instant::now();
    f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:34} {secs:9.3} s   {:10.2} M{unit}/s", items / secs / 1e6);
}

fn main() {
    println!("# hotpath_micro\n");
    let d = dataset("FL").unwrap();
    let n1 = 16384u64;

    // 1. Synthetic edge generation (workload setup, not T_LoC).
    let m = 5_000_000usize;
    let mut rng = Rng::new(1);
    let mut edges = (Vec::new(), Vec::new());
    rate("rmat_generate (5M edges)", m as f64, "edge", || {
        edges = RmatParams::default().sample_edges(&mut rng, d.n_vertices, m);
    });

    // 2. Fiber-Shard histogram (the dominant T_LoC term).
    let (src, dst) = &edges;
    let mut tc = None;
    rate("tile_histogram (5M edges)", m as f64, "edge", || {
        tc = Some(TileCounts::from_edges(src, dst, d.n_vertices, n1));
    });

    // 3. Kernel mapping + codegen (b5 = deepest model).
    let hw = HwConfig::alveo_u250();
    let tiles = d.tile_counts(n1);
    let ir = ZooModel::B5.build(d.meta());
    let mut exe = None;
    let t0 = Instant::now();
    for _ in 0..10 {
        exe = Some(compile(&ir, &tiles, &hw, CompileOptions::default()));
    }
    let secs = t0.elapsed().as_secs_f64() / 10.0;
    let exe = exe.unwrap();
    let n_instr = exe.program.total_instrs();
    println!(
        "{:34} {secs:9.5} s   {:10.2} Minstr/s  ({n_instr} instrs)",
        "compile b5/FL (avg of 10)",
        n_instr as f64 / secs / 1e6
    );

    // 4. Binary encode/decode round trip.
    let t0 = Instant::now();
    let bytes = exe.program.to_bytes();
    let enc = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let back = graphagile::isa::Program::from_bytes(&bytes).unwrap();
    let dec = t0.elapsed().as_secs_f64();
    assert_eq!(back.total_instrs(), n_instr);
    println!(
        "{:34} enc {enc:.5} s / dec {dec:.5} s ({:.1} MB)",
        "binary roundtrip b5/FL",
        bytes.len() as f64 / 1e6
    );

    // 5. Simulation rate.
    let t0 = Instant::now();
    let runs = 10;
    let mut cycles = 0;
    for _ in 0..runs {
        cycles = simulate(&exe.program, &hw).cycles;
    }
    let secs = t0.elapsed().as_secs_f64() / runs as f64;
    println!(
        "{:34} {secs:9.5} s   {:10.2} Minstr/s  ({cycles} cycles simulated)",
        "simulate b5/FL (avg of 10)",
        n_instr as f64 / secs / 1e6
    );
}
