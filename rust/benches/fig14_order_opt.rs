//! Regenerates paper Fig. 14: LoH speedup from the computation-order
//! optimization, averaged over datasets, per model b1-b8.
use graphagile::harness::bench_support::run_bench;
use graphagile::harness::tables;

fn main() {
    run_bench("fig14_order_opt", |ctx, datasets| tables::fig14(ctx, datasets));
}
