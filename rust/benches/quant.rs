//! Accuracy-vs-speed profile of the int8 ACK datapath, written to
//! `BENCH_quant.json` so the quantization trajectory is recorded across
//! commits. Three sections, three floors (enforced under
//! `GA_BENCH_STRICT=1`; the default run only asserts sanity so loaded
//! machines don't flake):
//!
//! * **kernels** — the int8 blocked GEMM and CSR SpDMM against their
//!   f32 twins on pre-quantized steady-state operands (the executor
//!   quantizes a tile row once and fuses requantize into the activation
//!   epilogue, so the core kernel is the per-visit cost that repeats;
//!   the epilogue pair is timed separately and reported as
//!   `requant_ms`). Floor: geomean speedup >= 2x.
//! * **ddr** — modeled operand traffic of the cycle simulator for the
//!   same program with and without a GA03 scale section, across the
//!   zoo. Floor: geomean bytes ratio <= 0.55x f32 (int8 shrinks
//!   operands 4x but edge-index traffic stays u32, so the ratio sits
//!   between 0.25 and 1).
//! * **top1** — agreement of int8 argmax classes vs the f32 golden on
//!   synthetic logits, per zoo model, with scales from the exact
//!   calibration profile. Floor: minimum agreement >= 99%.
//!
//! Determinism: `GA_BENCH_THREADS=<n>` pins the kernel worker count
//! (CI sets it).

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::exec::kernels::{
    csr_from_coo, dequant_bias_into, gemm_i8_packed_into, gemm_packed_into, kernel_threads,
    quantize_into, spdmm_csr_i8_into, spdmm_csr_into,
};
use graphagile::exec::{
    golden_forward, FunctionalExecutor, PackedWeights, PackedWeightsI8, RustBackend, WeightStore,
};
use graphagile::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
use graphagile::ir::ALL_MODELS;
use graphagile::isa::AggOp;
use graphagile::quant::{calibrate, CalibrationProfile};
use graphagile::sim::simulate_dynamic;
use std::hint::black_box;
use std::time::Instant;

/// Best-of-`reps` wall-clock in milliseconds.
fn ms(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    best
}

fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len().max(1) as f64).exp()
}

/// Deterministic pseudo-random values in [-1, 1) (xorshift; benches
/// must reproduce run-to-run).
fn fill(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
    (0..len)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 40) as f32 / (1u64 << 23) as f32 - 1.0
        })
        .collect()
}

fn absmax(v: &[f32]) -> f32 {
    v.iter().fold(0f32, |a, &x| a.max(x.abs()))
}

fn argmax_rows(logits: &[f32], c: usize) -> Vec<usize> {
    logits
        .chunks(c)
        .map(|row| {
            let mut best = 0;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

fn main() {
    let threads = kernel_threads();
    let strict = std::env::var("GA_BENCH_STRICT").as_deref() == Ok("1");

    // Section 1: kernel micro-bench, int8 vs f32. Equal-MAC GEMM
    // shapes spanning tall/mid/wide panels, then R-MAT gather at
    // serving feature widths.
    let gemm_grid = [
        ("gemm-tall", 4096usize, 128usize, 128usize),
        ("gemm-mid", 1024, 256, 256),
        ("gemm-wide", 512, 512, 256),
    ];
    let spdmm_grid =
        [("spdmm-mid", 4096u64, 65_536u64, 128usize), ("spdmm-wide", 2048, 65_536, 256)];
    let mut kernel_rows = Vec::new();
    let mut speedups = Vec::new();
    println!(
        "{:>12} {:>22} {:>10} {:>10} {:>8}",
        "kernel", "shape", "f32 (ms)", "int8 (ms)", "speedup"
    );
    for &(name, m, k, n) in &gemm_grid {
        let h = fill(1, m * k);
        let w = fill(2, k * n);
        let b = fill(3, n);
        let pw = PackedWeights::pack(&w, k, n);
        let mut out = vec![0f32; m * n];
        gemm_packed_into(&h, m, &pw, &b, &mut out); // warm
        let f32_ms = ms(3, || gemm_packed_into(&h, m, &pw, &b, black_box(&mut out)));

        let (sx, sw) = (absmax(&h) / 127.0, absmax(&w) / 127.0);
        let pwq = PackedWeightsI8::pack(&w, k, n, sw);
        let mut hq = vec![0i8; m * k];
        quantize_into(&h, sx, &mut hq);
        let mut acc = vec![0i32; m * n];
        gemm_i8_packed_into(&hq, m, &pwq, &mut acc); // warm
        let i8_ms = ms(3, || gemm_i8_packed_into(&hq, m, &pwq, black_box(&mut acc)));
        // The fused epilogue pair, timed apart: it runs once per tile
        // visit, amortized over the activation pass it fuses into.
        let requant_ms = ms(3, || {
            quantize_into(&h, sx, black_box(&mut hq));
            dequant_bias_into(&acc, n, sx * sw, &b, black_box(&mut out));
        });
        let s = f32_ms / i8_ms.max(1e-9);
        speedups.push(s);
        let shape = format!("{m}x{k}x{n}");
        println!("{:>12} {:>22} {:>10.3} {:>10.3} {:>7.2}x", name, shape, f32_ms, i8_ms, s);
        kernel_rows.push(format!(
            "    {{\"kernel\": \"{name}\", \"m\": {m}, \"k\": {k}, \"n\": {n}, \
             \"f32_ms\": {f32_ms:.4}, \"int8_ms\": {i8_ms:.4}, \
             \"requant_ms\": {requant_ms:.4}, \"speedup\": {s:.3}}}"
        ));
    }
    for &(name, nv, ne, f) in &spdmm_grid {
        let meta = GraphMeta::new(name, nv, ne, f as u64, 8);
        let g = rmat_edges(meta, Default::default(), 23).gcn_normalized();
        let csr = csr_from_coo(&g.src, &g.dst, nv as usize);
        let h = fill(4, nv as usize * f);
        let mut acc_f = vec![0f32; nv as usize * f];
        let mut touched = vec![0u32; nv as usize];
        spdmm_csr_into(&csr, &g.w, &h, f, AggOp::Sum, &mut acc_f, &mut touched); // warm
        let f32_ms = ms(3, || {
            spdmm_csr_into(&csr, &g.w, &h, f, AggOp::Sum, black_box(&mut acc_f), &mut touched);
        });

        let (sx, se) = (absmax(&h) / 127.0, absmax(&g.w) / 127.0);
        let mut hq = vec![0i8; h.len()];
        quantize_into(&h, sx, &mut hq);
        let mut ewq = vec![0i8; g.w.len()];
        quantize_into(&g.w, se, &mut ewq);
        let mut acc = vec![0i32; nv as usize * f];
        spdmm_csr_i8_into(&csr, &ewq, &hq, f, &mut acc, &mut touched); // warm
        let i8_ms = ms(3, || {
            spdmm_csr_i8_into(&csr, &ewq, &hq, f, black_box(&mut acc), &mut touched);
        });
        let s = f32_ms / i8_ms.max(1e-9);
        speedups.push(s);
        let shape = format!("|V|={nv} |E|={ne} f={f}");
        println!("{:>12} {:>22} {:>10.3} {:>10.3} {:>7.2}x", name, shape, f32_ms, i8_ms, s);
        kernel_rows.push(format!(
            "    {{\"kernel\": \"{name}\", \"vertices\": {nv}, \"edges\": {ne}, \"feat\": {f}, \
             \"f32_ms\": {f32_ms:.4}, \"int8_ms\": {i8_ms:.4}, \"speedup\": {s:.3}}}"
        ));
    }
    let kernel_geomean = geomean(&speedups);

    // Sections 2 + 3: modeled DDR traffic and top-1 agreement. One
    // shared graph across the zoo; n_classes matches the zoo head.
    let meta = GraphMeta::new("quant-zoo", 1024, 8192, 64, 8);
    let g = rmat_edges(meta, Default::default(), 29).gcn_normalized();
    let hw = HwConfig::functional_tiles();
    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    let pg = PartitionedGraph::build(&g, cfg);
    let x = g.random_features(5);
    let mut zoo_rows = Vec::new();
    let mut ratios = Vec::new();
    let mut agreements = Vec::new();
    println!("\n{:>6} {:>12} {:>12} {:>8} {:>8}", "model", "f32 MB", "int8 MB", "ratio", "top1");
    for model in ALL_MODELS {
        let ir = model.build(g.meta.clone());
        let mut exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        let f32_sim = simulate_dynamic(&exe.program, &hw);
        assert_eq!(f32_sim.quant_blocks, 0, "unscaled program charged int8 blocks");

        let cal = calibrate(&exe.ir, &store, &CalibrationProfile::exact(&g, &x));
        exe.program.scales = Some(cal.table);
        let q_sim = simulate_dynamic(&exe.program, &hw);
        assert!(q_sim.quant_blocks > 0, "{}: scaled program never quantized", model.key());
        let ratio = q_sim.total_mem_bytes as f64 / f32_sim.total_mem_bytes.max(1) as f64;
        ratios.push(ratio);

        let golden = golden_forward(&exe.ir, &g, &store, &x);
        let got = FunctionalExecutor::new(&exe, &pg, &store, RustBackend).run(&x);
        let c = g.meta.n_classes as usize;
        let (gold_top, got_top) = (argmax_rows(&golden, c), argmax_rows(&got, c));
        let same = gold_top.iter().zip(&got_top).filter(|(a, b)| a == b).count();
        let agree = same as f64 / gold_top.len().max(1) as f64;
        agreements.push(agree);
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>8.3} {:>7.1}%",
            model.key(),
            f32_sim.total_mem_bytes as f64 / 1e6,
            q_sim.total_mem_bytes as f64 / 1e6,
            ratio,
            agree * 100.0
        );
        zoo_rows.push(format!(
            "    {{\"model\": \"{}\", \"f32_bytes\": {}, \"int8_bytes\": {}, \
             \"bytes_ratio\": {ratio:.4}, \"top1_agreement\": {agree:.4}, \
             \"calibrated_bound\": {:.6}}}",
            model.key(),
            f32_sim.total_mem_bytes,
            q_sim.total_mem_bytes,
            cal.bound
        ));
    }
    let ddr_ratio = geomean(&ratios);
    let top1_min = agreements.iter().cloned().fold(1.0f64, f64::min);

    println!(
        "\nint8 kernel geomean {kernel_geomean:.2}x ({threads} threads), \
         modeled DDR {ddr_ratio:.3}x f32, worst top-1 agreement {:.1}%",
        top1_min * 100.0
    );
    let json = format!(
        "{{\n  \"bench\": \"quant\",\n  \"threads\": {threads},\n  \
         \"geomean_kernel_speedup\": {kernel_geomean:.4},\n  \
         \"ddr_bytes_ratio\": {ddr_ratio:.4},\n  \"top1_agreement_min\": {top1_min:.4},\n  \
         \"floors\": {{\"kernel_speedup\": 2.0, \"ddr_bytes_ratio\": 0.55, \
         \"top1_agreement\": 0.99}},\n  \"kernels\": [\n{}\n  ],\n  \"zoo\": [\n{}\n  ]\n}}\n",
        kernel_rows.join(",\n"),
        zoo_rows.join(",\n")
    );
    std::fs::write("BENCH_quant.json", &json).expect("write BENCH_quant.json");
    eprintln!(
        "wrote BENCH_quant.json (kernels {kernel_geomean:.2}x, ddr {ddr_ratio:.3}x, \
         top1 {:.1}%)",
        top1_min * 100.0
    );

    // Sanity on every run: int8 must never lose to f32, traffic must
    // shrink, and classes must mostly agree.
    assert!(kernel_geomean > 1.0, "int8 kernels slower than f32 ({kernel_geomean:.2}x)");
    assert!(ddr_ratio < 1.0, "quantized program moved more bytes ({ddr_ratio:.3}x)");
    assert!(top1_min > 0.9, "top-1 agreement collapsed ({:.1}%)", top1_min * 100.0);
    // Acceptance floors, enforced on demand.
    if strict {
        assert!(
            kernel_geomean >= 2.0,
            "int8 kernel geomean {kernel_geomean:.2}x below the 2x floor"
        );
        assert!(ddr_ratio <= 0.55, "modeled DDR ratio {ddr_ratio:.3}x above the 0.55x ceiling");
        assert!(top1_min >= 0.99, "top-1 agreement {:.2}% below the 99% floor", top1_min * 100.0);
    }
}
