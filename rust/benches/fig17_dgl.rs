//! Regenerates paper Fig. 17: end-to-end latency vs DGL-CPU / DGL-GPU
//! (b1-b7).
use graphagile::harness::bench_support::run_bench;
use graphagile::harness::tables;

fn main() {
    run_bench("fig17_dgl", |ctx, datasets| tables::fig17(ctx, datasets));
}
