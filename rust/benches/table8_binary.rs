//! Regenerates paper Table 8: compiled binary sizes (MB) + input sizes.
use graphagile::harness::bench_support::run_bench;
use graphagile::harness::tables;

fn main() {
    run_bench("table8_binary", |ctx, _| tables::table8(ctx));
}
