//! Serving-fleet benchmark: throughput and latency percentiles vs device
//! count (1/2/4) on a deterministic mixed-tenant workload, written to
//! `BENCH_serve.json` so the serving perf trajectory is recorded across
//! commits. Everything runs on the virtual clock — the numbers are
//! bit-identical between runs, so a diff of the JSON is a real regression.
//!
//! Workloads are first-class traces: the synthesized request stream is
//! round-tripped through the `daemon::Trace` codec before serving (any
//! encode/decode drift would corrupt the bench input and fail loudly),
//! and `GA_TRACE=path.json` replaces the synthesized stream with the
//! admitted requests of a daemon-recorded trace.
//!
//! Knobs: `GA_REQUESTS` (default 400), `GA_TRACE` (recorded trace path).

use graphagile::config::HwConfig;
use graphagile::daemon::Trace;
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::serve::{Coordinator, FleetConfig, Request};
use graphagile::util::Rng;
use std::path::Path;

fn workload(n: usize, seed: u64) -> Vec<Request> {
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B6, ZooModel::B7];
    let graphs = [
        dataset("CI").unwrap(),
        dataset("CO").unwrap(),
        dataset("PU").unwrap(),
    ];
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            Request::full(
                rng.below(8) as u32,
                models[rng.below(4) as usize],
                graphs[rng.below(3) as usize],
                i as f64 * 5e-5,
            )
        })
        .collect()
}

/// The bench input: a recorded trace when `GA_TRACE` is set, else the
/// synthesized workload round-tripped through the trace codec.
fn bench_requests(n: usize) -> Vec<Request> {
    if let Ok(path) = std::env::var("GA_TRACE") {
        let t = Trace::load(Path::new(&path)).expect("loading GA_TRACE");
        let reqs = t.requests();
        eprintln!("using recorded trace {path} ({} admitted requests)", reqs.len());
        return reqs;
    }
    let trace =
        Trace::from_requests(HwConfig::alveo_u250(), FleetConfig::default(), workload(n, 11));
    let decoded = Trace::parse(&trace.encode()).expect("trace round-trip");
    assert_eq!(decoded, trace, "trace codec must round-trip the bench workload");
    decoded.requests()
}

fn main() {
    let n: usize = std::env::var("GA_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let reqs = bench_requests(n);
    let n = reqs.len();
    let mut rows = Vec::new();
    println!(
        "{:>8} {:>14} {:>10} {:>10} {:>7} {:>10} {:>8}",
        "devices", "thr (req/s)", "p50 (ms)", "p99 (ms)", "hits", "coalesced", "remaps"
    );
    for devices in [1usize, 2, 4] {
        let cfg = FleetConfig { n_devices: devices, ..FleetConfig::default() };
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        let stats = c.run(reqs.clone());
        let thr = stats.completed as f64 / stats.makespan;
        println!(
            "{:>8} {:>14.0} {:>10.3} {:>10.3} {:>7} {:>10} {:>8}",
            devices,
            thr,
            stats.p50 * 1e3,
            stats.p99 * 1e3,
            stats.cache_hits,
            stats.coalesced,
            stats.remaps
        );
        rows.push(format!(
            "    {{\"devices\": {}, \"throughput_rps\": {:.1}, \"p50_ms\": {:.4}, \
             \"p99_ms\": {:.4}, \"mean_ms\": {:.4}, \"hit_rate\": {:.4}, \
             \"coalesced\": {}, \"remaps\": {}, \"makespan_s\": {:.6}}}",
            devices,
            thr,
            stats.p50 * 1e3,
            stats.p99 * 1e3,
            stats.mean * 1e3,
            c.hit_rate(),
            stats.coalesced,
            stats.remaps,
            stats.makespan,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"serve_fleet\",\n  \"requests\": {n},\n  \"fleet\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_serve.json", &json).expect("write BENCH_serve.json");
    eprintln!("wrote BENCH_serve.json ({n} requests, devices 1/2/4)");
}
