//! Regenerates paper Table 10: hardware-execution latency vs BoostGCN /
//! HyGCN / AWB-GCN on b2 (FL, RE, YE, AP).
use graphagile::harness::bench_support::run_bench;
use graphagile::harness::tables;

fn main() {
    run_bench("table10_accels", |ctx, _| tables::table10(ctx));
}
