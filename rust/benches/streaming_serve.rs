//! Streaming-update benchmark, two halves written to
//! `BENCH_streaming.json`:
//!
//! 1. **Apply vs full rebuild** (wall-clock): a ~1% edge-churn batch
//!    applied through the incremental dirty-subshard path, against
//!    re-running the full `PartitionedGraph::build` partition pass on
//!    the materialized epoch. The floor (`GA_BENCH_STRICT=1`) demands
//!    >= 5x — the whole point of incremental recompilation.
//! 2. **Serving across epochs** (virtual clock, deterministic): a
//!    mini-batch trace with churn batches interleaved every
//!    `UPDATE_EVERY` requests, against the identical trace with the
//!    updates stripped. Bucket executables are shape-only, so the
//!    floor demands the bucket-cache hit rate survive the epoch bumps
//!    (within 2% of the update-free trace).
//!
//! The serve-trace half is a first-class `daemon::Trace`: the
//! synthesized stream is round-tripped through the trace codec before
//! serving (the off-registry `RM` dataset exercises the codec's
//! interning path), and `GA_TRACE=path.json` substitutes a
//! daemon-recorded trace for the synthesized one.
//!
//! Knobs: `GA_REQUESTS` (default 1000), `GA_EPOCHS` (default 5 apply
//! measurements), `GA_TRACE` (recorded trace path). Floors are enforced
//! only under `GA_BENCH_STRICT=1` (the wall-clock half stays
//! report-only on loaded PR runners; CI enforces on pushes to main).

use graphagile::config::HwConfig;
use graphagile::daemon::Trace;
use graphagile::graph::{
    rmat_edges, Dataset, GraphMeta, PartitionConfig, PartitionedGraph, TileCounts,
};
use graphagile::ir::ZooModel;
use graphagile::serve::{Coordinator, FleetConfig, Request, ServeStats};
use graphagile::stream::{ChurnGenerator, ChurnSpec, DynamicGraph};
use graphagile::util::{timed, Rng};
use std::path::Path;

/// The serve-trace graph (same scale as the mini-batch bench).
const RMAT_TRACE: Dataset = Dataset {
    key: "RM",
    name: "R-MAT-stream",
    n_vertices: 32_768,
    n_edges: 262_144,
    feat_len: 64,
    n_classes: 8,
    locality: 0.4,
};

const MODELS: [ZooModel; 4] = [ZooModel::B1, ZooModel::B2, ZooModel::B6, ZooModel::B7];
const SPACING_S: f64 = 1e-3;
const UPDATE_EVERY: usize = 50;

/// Half 1: wall-clock apply-vs-rebuild on a fine partition (N1 = 128:
/// 256x256 subshards, so a 1% churn batch dirties a few percent of the
/// tiles and the incremental path's advantage is structural, not
/// noise).
fn bench_apply(epochs: u32) -> (f64, f64, f64, f64) {
    let meta = GraphMeta::new("stream-micro", 32_768, 262_144, 8, 2);
    let g = rmat_edges(meta, RMAT_TRACE.params(), 42);
    let cfg = PartitionConfig { n1: 128, n2: 8 };
    let mut d = DynamicGraph::new(g, cfg);
    let mut gen = ChurnGenerator::new(RMAT_TRACE.params(), 7);
    let spec = ChurnSpec { inserts: 2621, deletes: 655, new_vertices: 0 };
    let mut t_apply = 0.0f64;
    let mut t_full = 0.0f64;
    let mut dirty_frac = 0.0f64;
    for e in 0..epochs {
        let batch = gen.next_batch(&d, spec);
        let (report, t_inc) = timed(|| d.apply(&batch));
        t_apply += t_inc;
        dirty_frac += report.dirty_subshards as f64 / report.total_subshards as f64;
        let materialized = d.materialize(d.epoch());
        let (scratch, t_build) = timed(|| PartitionedGraph::build(&materialized, cfg));
        t_full += t_build;
        if e == 0 {
            // Correctness spot-check (full equality is pinned in
            // rust/tests/streaming.rs): live tile counts agree.
            assert_eq!(d.tile_counts(), TileCounts::from_coo(&materialized, cfg.n1));
            assert_eq!(scratch.shards, d.shards());
        }
    }
    let n = epochs.max(1) as f64;
    (t_apply / n, t_full / n, t_full / t_apply.max(1e-12), dirty_frac / n)
}

/// The update-interleaved trace. The RNG draws happen before the
/// update-slot branch, so every non-update request is identical
/// whether or not the updates are later stripped — the "static"
/// comparison really is the same trace minus the churn.
fn minibatch_trace(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let tenant = rng.below(8) as u32;
            let model = MODELS[rng.below(4) as usize];
            let k = 1 + rng.below(2) as usize;
            let targets: Vec<u32> =
                (0..k).map(|_| rng.below(RMAT_TRACE.n_vertices) as u32).collect();
            let arrival = i as f64 * SPACING_S;
            if i % UPDATE_EVERY == UPDATE_EVERY - 1 {
                return Request::update(tenant, RMAT_TRACE, 2621, 655, 0, i as u64, arrival);
            }
            Request::minibatch(
                tenant,
                model,
                RMAT_TRACE,
                targets,
                vec![15, 10],
                seed ^ i as u64,
                arrival,
            )
        })
        .collect()
}

/// The bench input: a recorded trace when `GA_TRACE` is set, else the
/// synthesized update-interleaved stream round-tripped through the
/// trace codec (codec drift fails loudly instead of skewing numbers).
fn bench_requests(n: usize) -> Vec<Request> {
    if let Ok(path) = std::env::var("GA_TRACE") {
        let t = Trace::load(Path::new(&path)).expect("loading GA_TRACE");
        let reqs = t.requests();
        eprintln!("using recorded trace {path} ({} admitted requests)", reqs.len());
        return reqs;
    }
    let trace = Trace::from_requests(
        HwConfig::alveo_u250(),
        FleetConfig { n_devices: 2, ..FleetConfig::default() },
        minibatch_trace(n, 11),
    );
    let decoded = Trace::parse(&trace.encode()).expect("trace round-trip");
    assert_eq!(decoded, trace, "trace codec must round-trip the bench workload");
    decoded.requests()
}

fn serve(reqs: Vec<Request>) -> ServeStats {
    let cfg = FleetConfig { n_devices: 2, ..FleetConfig::default() };
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
    c.run(reqs)
}

fn hit_rate(s: &ServeStats) -> f64 {
    s.bucket_hits as f64 / s.minibatched.max(1) as f64
}

fn main() {
    let n: usize = std::env::var("GA_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let epochs: u32 = std::env::var("GA_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);

    let (apply_s, full_s, speedup, dirty_frac) = bench_apply(epochs);
    println!(
        "incremental apply {:.3} ms vs full rebuild {:.3} ms -> {:.1}x \
         ({:.1}% subshards dirty per 1% churn batch)",
        apply_s * 1e3,
        full_s * 1e3,
        speedup,
        dirty_frac * 100.0
    );

    let full_trace = bench_requests(n);
    let stripped: Vec<Request> = full_trace
        .iter()
        .filter(|r| !r.target.is_update())
        .cloned()
        .collect();
    let stream = serve(full_trace);
    let stat = serve(stripped);
    let (hr_stream, hr_static) = (hit_rate(&stream), hit_rate(&stat));
    println!(
        "{:>10} {:>10} {:>10} {:>12} {:>8} {:>8} {:>12}",
        "trace", "p50 (ms)", "p99 (ms)", "bucket hits", "epochs", "dirty", "invalidated"
    );
    println!(
        "{:>10} {:>10.4} {:>10.4} {:>12.4} {:>8} {:>8} {:>12}",
        "stream",
        stream.p50 * 1e3,
        stream.p99 * 1e3,
        hr_stream,
        stream.max_epoch,
        stream.dirty_subshards,
        stream.invalidated
    );
    println!(
        "{:>10} {:>10.4} {:>10.4} {:>12.4} {:>8} {:>8} {:>12}",
        "static",
        stat.p50 * 1e3,
        stat.p99 * 1e3,
        hr_static,
        stat.max_epoch,
        stat.dirty_subshards,
        stat.invalidated
    );

    let json = format!(
        "{{\n  \"bench\": \"streaming_serve\",\n  \"requests\": {n},\n  \
         \"apply_epochs\": {epochs},\n  \
         \"apply_ms\": {:.4},\n  \"full_rebuild_ms\": {:.4},\n  \
         \"apply_speedup\": {speedup:.2},\n  \"dirty_fraction\": {dirty_frac:.4},\n  \
         \"updates\": {},\n  \"max_epoch\": {},\n  \
         \"dirty_subshards\": {},\n  \"rebuilt_edges\": {},\n  \
         \"invalidated\": {},\n  \"compactions\": {},\n  \
         \"bucket_hit_rate_stream\": {hr_stream:.4},\n  \
         \"bucket_hit_rate_static\": {hr_static:.4},\n  \
         \"p50_stream_ms\": {:.4},\n  \"p50_static_ms\": {:.4},\n  \
         \"floors\": {{\"apply_speedup\": 5.0, \"bucket_hit_rate_drop_max\": 0.02}}\n}}\n",
        apply_s * 1e3,
        full_s * 1e3,
        stream.updates,
        stream.max_epoch,
        stream.dirty_subshards,
        stream.rebuilt_edges,
        stream.invalidated,
        stream.compactions,
        stream.p50 * 1e3,
        stat.p50 * 1e3,
    );
    std::fs::write("BENCH_streaming.json", &json).expect("write BENCH_streaming.json");
    eprintln!(
        "wrote BENCH_streaming.json ({n} requests, apply speedup {speedup:.1}x, \
         bucket hit rate {hr_stream:.3} vs {hr_static:.3} static)"
    );

    // Sanity that holds on any machine (virtual clock: deterministic).
    // A GA_TRACE-supplied recording may legitimately contain no churn
    // or no mini-batches, so the shape invariants only bind on the
    // synthesized workload.
    if std::env::var("GA_TRACE").is_err() {
        assert!(stream.updates > 0);
        assert_eq!(stream.max_epoch as u64, stream.updates);
        assert!(stream.minibatched > 0 && stat.minibatched > 0);
    }
    // Acceptance floors, enforced on demand (main-branch CI sets
    // GA_BENCH_STRICT=1): the incremental apply must beat a full
    // rebuild >= 5x on a 1% churn batch, and graph churn must not
    // disturb the shape-only bucket cache.
    if std::env::var("GA_BENCH_STRICT").as_deref() == Ok("1") {
        assert!(
            speedup >= 5.0,
            "apply speedup {speedup:.2}x below the 5x floor"
        );
        assert!(
            hr_stream >= hr_static - 0.02,
            "bucket hit rate dropped across epochs: {hr_stream:.4} vs {hr_static:.4}"
        );
    }
}
