//! Regenerates paper Fig. 18: end-to-end latency vs PyG-CPU / PyG-GPU
//! (b1-b8), including the paper's OOM cells.
use graphagile::harness::bench_support::run_bench;
use graphagile::harness::tables;

fn main() {
    run_bench("fig18_pyg", |ctx, datasets| tables::fig18(ctx, datasets));
}
