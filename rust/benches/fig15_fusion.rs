//! Regenerates paper Fig. 15: LoH speedup from layer fusion.
use graphagile::harness::bench_support::run_bench;
use graphagile::harness::tables;

fn main() {
    run_bench("fig15_fusion", |ctx, datasets| tables::fig15(ctx, datasets));
}
