//! Span-tracer overhead benchmark: the same deterministic mixed
//! workload served with tracing off and tracing on. Written to
//! `BENCH_obs.json` so the observability tax is recorded across
//! commits.
//!
//! Invariants (enforced strict or not): the traced run's responses and
//! stats are bit-identical to the untraced run's (tracing only
//! observes), the dormant tracer records zero spans, and the live one
//! records a span tree for every admitted request.
//!
//! Strict gate (`GA_BENCH_STRICT=1`): tracing-on p50 wall-clock stays
//! within 1.05x the tracing-off p50.
//!
//! Knobs: `GA_REQUESTS` (default 400), `GA_RUNS` (default 9).

use graphagile::config::HwConfig;
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::serve::{Coordinator, CostModel, FleetConfig, Precision, Request};
use graphagile::util::{timed, Rng};

const DEVICES: usize = 2;
const SPACING_S: f64 = 1e-4;

/// Mixed workload: whole-graph f32 and int8, mini-batch ego-nets, and
/// churn batches — every serving path the tracer must cover.
fn mixed_workload(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B7];
    let graphs = [dataset("CO").unwrap(), dataset("PU").unwrap()];
    (0..n)
        .map(|i| {
            let tenant = rng.below(4) as u32;
            let ds = graphs[rng.below(2) as usize];
            let model = models[rng.below(3) as usize];
            let arrival = i as f64 * SPACING_S;
            match rng.below(8) {
                0 => Request::update(
                    tenant,
                    ds,
                    16 + rng.below(48) as u32,
                    rng.below(8) as u32,
                    rng.below(3) as u32,
                    seed ^ i as u64,
                    arrival,
                ),
                1 | 2 => {
                    let k = 1 + rng.below(3) as usize;
                    let targets =
                        (0..k).map(|_| rng.below(ds.n_vertices) as u32).collect();
                    Request::minibatch(
                        tenant,
                        model,
                        ds,
                        targets,
                        vec![8, 4],
                        seed.wrapping_add(i as u64),
                        arrival,
                    )
                }
                3 => Request::full(tenant, model, ds, arrival)
                    .with_precision(Precision::Int8),
                _ => Request::full(tenant, model, ds, arrival),
            }
        })
        .collect()
}

/// One full serve of the workload; returns the coordinator and the
/// wall-clock seconds `run` took.
fn serve(reqs: &[Request], traced: bool) -> (Coordinator, f64) {
    let cfg = FleetConfig {
        n_devices: DEVICES,
        costs: CostModel { deadline_s: f64::INFINITY, ..CostModel::default() },
        ..FleetConfig::default()
    };
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
    c.set_tracing(traced);
    let work = reqs.to_vec();
    let (_, secs) = timed(|| c.run(work));
    (c, secs)
}

/// Median of a sample set (nearest-rank on the sorted copy).
fn p50(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(f64::total_cmp);
    s[s.len() / 2]
}

fn main() {
    let n: usize = std::env::var("GA_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let runs: usize = std::env::var("GA_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(9);
    let strict = std::env::var("GA_BENCH_STRICT").ok().as_deref() == Some("1");

    let reqs = mixed_workload(n, 41);

    // One warmup serve per variant (cache-cold compile paths, page-in),
    // then `runs` timed serves each.
    serve(&reqs, false);
    serve(&reqs, true);
    let mut off_times = Vec::with_capacity(runs);
    let mut on_times = Vec::with_capacity(runs);
    let (off_c, t) = serve(&reqs, false);
    off_times.push(t);
    let (on_c, t) = serve(&reqs, true);
    on_times.push(t);
    for _ in 1..runs {
        off_times.push(serve(&reqs, false).1);
        on_times.push(serve(&reqs, true).1);
    }

    // Tracing only observes: byte-identical serving either way.
    assert_eq!(off_c.responses, on_c.responses, "tracing changed a response");
    assert_eq!(off_c.stats(), on_c.stats(), "tracing changed the stats");
    assert_eq!(off_c.spans().len(), 0, "dormant tracer recorded spans");
    assert!(on_c.spans().len() >= n, "live tracer must span every request");

    let chrome = on_c.chrome_trace_json();
    let off_p50 = p50(&off_times);
    let on_p50 = p50(&on_times);
    let ratio = if off_p50 > 0.0 { on_p50 / off_p50 } else { f64::INFINITY };

    println!(
        "{:>12} {:>12} {:>12} {:>9}",
        "variant", "p50 (ms)", "spans", "ratio"
    );
    println!("{:>12} {:>12.3} {:>12} {:>9}", "tracing-off", off_p50 * 1e3, 0, "-");
    println!(
        "{:>12} {:>12.3} {:>12} {:>8.3}x",
        "tracing-on",
        on_p50 * 1e3,
        on_c.spans().len(),
        ratio
    );

    let json = format!(
        "{{\n  \"bench\": \"obs_overhead\",\n  \"requests\": {n},\n  \"runs\": {runs},\n  \
         \"devices\": {DEVICES},\n  \"off_p50_s\": {off_p50:.6},\n  \
         \"on_p50_s\": {on_p50:.6},\n  \"spans\": {},\n  \
         \"chrome_trace_bytes\": {},\n  \
         \"gates\": {{\"overhead_ratio\": {ratio:.6}}}\n}}\n",
        on_c.spans().len(),
        chrome.len(),
    );
    std::fs::write("BENCH_obs.json", &json).expect("write BENCH_obs.json");
    eprintln!("wrote BENCH_obs.json ({n} requests, {runs} runs)");

    if strict {
        assert!(
            ratio <= 1.05,
            "STRICT: tracing-on p50 ({:.3} ms) exceeds 1.05 x tracing-off \
             ({:.3} ms) — ratio {ratio:.3}x",
            on_p50 * 1e3,
            off_p50 * 1e3,
        );
        eprintln!("STRICT gate passed: overhead ratio {ratio:.3}x <= 1.05x");
    }
}
