//! Regenerates paper Fig. 16: LoH speedup from overlapping computation
//! with data communication (double/triple buffering).
use graphagile::harness::bench_support::run_bench;
use graphagile::harness::tables;

fn main() {
    run_bench("fig16_overlap", |ctx, datasets| tables::fig16(ctx, datasets));
}
