//! Naive vs optimized kernel backend across the model zoo x an R-MAT
//! grid, written to `BENCH_kernels.json` so the kernel-backend
//! trajectory is recorded across commits.
//!
//! Two comparisons per (model, graph) cell, both running the *same*
//! compiled numerics:
//! * **kernels** — whole-graph execution (`golden_forward_reference`
//!   vs `golden_forward_in`): the GEMM/SpDMM/SDDMM trio at full |V|/|E|
//!   sizes, where blocking, CSR and row-parallelism have the most room;
//! * **tile** — the partition-centric executor (`ReferenceBackend` vs
//!   `RustBackend`): the serving hot path, including the executor-level
//!   wins (no per-subshard COO rebuilds or partial matrices, arena
//!   reuse).
//!
//! Optimized timings are steady-state (warm arena, weights packed once)
//! — exactly the regime the serving fleet runs in; the naive side is
//! the legacy per-call-allocating path. Each side is additionally
//! measured single-threaded (`GA_KERNEL_THREADS=1`) to isolate the
//! blocked+CSR win from the thread fan-out.
//!
//! Determinism: `GA_BENCH_THREADS=<n>` pins the kernel worker count
//! (CI sets it). Results are asserted strictly-faster by default; the
//! acceptance floors (>= 3x multi-thread geomean, >= 1.5x single-thread
//! geomean) are enforced when `GA_BENCH_STRICT=1` so loaded machines
//! don't flake the default run.

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::exec::kernels::kernel_threads;
use graphagile::exec::{
    golden_forward_in, golden_forward_reference, BufferArena, FunctionalExecutor,
    ReferenceBackend, RustBackend, WeightStore,
};
use graphagile::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
use graphagile::ir::ALL_MODELS;
use std::hint::black_box;
use std::time::Instant;

/// Best-of-`reps` wall-clock in milliseconds (min filters scheduler
/// noise out of single samples, so the strictly-faster assertion below
/// can't flake on a loaded machine).
fn ms(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    best
}

fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len().max(1) as f64).exp()
}

/// Run `phase` with the kernel pool pinned to one worker, restoring the
/// previous setting afterwards.
fn single_threaded<T>(phase: impl FnOnce() -> T) -> T {
    let prev = std::env::var("GA_KERNEL_THREADS").ok();
    std::env::set_var("GA_KERNEL_THREADS", "1");
    let out = phase();
    match prev {
        Some(v) => std::env::set_var("GA_KERNEL_THREADS", v),
        None => std::env::remove_var("GA_KERNEL_THREADS"),
    }
    out
}

fn main() {
    let threads = kernel_threads();
    // (name, |V|, |E|, feature length): sparse, mid, and dense cells —
    // the same densities the dynamic-sparsity grid spans, at sizes
    // where every kernel is past its parallel threshold.
    let grid = [
        ("rmat-sparse", 4096u64, 16_384u64, 64u64),
        ("rmat-mid", 1024, 49_152, 128),
        ("rmat-dense", 512, 49_152, 256),
    ];
    let hw = HwConfig::functional_tiles();
    let mut rows = Vec::new();
    let (mut g_mt, mut g_st, mut t_mt, mut t_st) = (vec![], vec![], vec![], vec![]);
    println!(
        "{:>6} {:>12} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "model", "graph", "naive (ms)", "kern mt", "kern st", "tile mt", "tile st"
    );
    for model in ALL_MODELS {
        for &(name, nv, ne, f) in &grid {
            let meta = GraphMeta::new(name, nv, ne, f, 8);
            let g = rmat_edges(meta, Default::default(), 17).gcn_normalized();
            let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
            let pg = PartitionedGraph::build(&g, cfg);
            let ir = model.build(g.meta.clone());
            let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
            let store = WeightStore::deterministic(&exe.ir, 33);
            let x = g.random_features(5);

            // Whole-graph kernels: naive vs optimized (warm arena).
            let naive_g = ms(2, || {
                black_box(golden_forward_reference(&exe.ir, &g, &store, &x));
            });
            let mut arena = BufferArena::new();
            black_box(golden_forward_in(&exe.ir, &g, &store, &x, &mut arena)); // warm
            let opt_g = ms(3, || {
                black_box(golden_forward_in(&exe.ir, &g, &store, &x, &mut arena));
            });
            let opt_g_st = single_threaded(|| {
                ms(2, || {
                    black_box(golden_forward_in(&exe.ir, &g, &store, &x, &mut arena));
                })
            });

            // Tile path: naive backend vs optimized backend (steady
            // state: warm arena + packed weights).
            let mut naive_fx = FunctionalExecutor::new(&exe, &pg, &store, ReferenceBackend);
            let naive_t = ms(2, || {
                black_box(naive_fx.run(&x));
            });
            let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
            black_box(fx.run(&x)); // warm
            let opt_t = ms(3, || {
                black_box(fx.run(&x));
            });
            let opt_t_st = single_threaded(|| {
                ms(2, || {
                    black_box(fx.run(&x));
                })
            });

            let (sg, sg_st) = (naive_g / opt_g.max(1e-9), naive_g / opt_g_st.max(1e-9));
            let (st, st_st) = (naive_t / opt_t.max(1e-9), naive_t / opt_t_st.max(1e-9));
            g_mt.push(sg);
            g_st.push(sg_st);
            t_mt.push(st);
            t_st.push(st_st);
            println!(
                "{:>6} {:>12} {:>12.3} {:>9.2}x {:>9.2}x {:>9.2}x {:>9.2}x",
                model.key(),
                name,
                naive_g,
                sg,
                sg_st,
                st,
                st_st
            );
            rows.push(format!(
                "    {{\"model\": \"{}\", \"graph\": \"{name}\", \"vertices\": {nv}, \
                 \"edges\": {ne}, \"feat\": {f}, \
                 \"naive_kernels_ms\": {naive_g:.4}, \"opt_kernels_ms\": {opt_g:.4}, \
                 \"opt_kernels_st_ms\": {opt_g_st:.4}, \
                 \"naive_tile_ms\": {naive_t:.4}, \"opt_tile_ms\": {opt_t:.4}, \
                 \"opt_tile_st_ms\": {opt_t_st:.4}, \
                 \"speedup_kernels\": {sg:.3}, \"speedup_kernels_st\": {sg_st:.3}, \
                 \"speedup_tile\": {st:.3}, \"speedup_tile_st\": {st_st:.3}}}",
                model.key(),
            ));
        }
    }
    let (gm_mt, gm_st) = (geomean(&g_mt), geomean(&g_st));
    let (gt_mt, gt_st) = (geomean(&t_mt), geomean(&t_st));
    println!(
        "\ngeomean speedups ({} threads): kernels {gm_mt:.2}x (st {gm_st:.2}x), \
         tile {gt_mt:.2}x (st {gt_st:.2}x)",
        threads
    );
    let json = format!(
        "{{\n  \"bench\": \"kernel_backend\",\n  \"threads\": {threads},\n  \
         \"cells\": {},\n  \"geomean_kernels_mt\": {gm_mt:.4},\n  \
         \"geomean_kernels_st\": {gm_st:.4},\n  \"geomean_tile_mt\": {gt_mt:.4},\n  \
         \"geomean_tile_st\": {gt_st:.4},\n  \"floors\": \
         {{\"mt\": 3.0, \"st\": 1.5}},\n  \"grid\": [\n{}\n  ]\n}}\n",
        rows.len(),
        rows.join(",\n")
    );
    std::fs::write("BENCH_kernels.json", &json).expect("write BENCH_kernels.json");
    eprintln!(
        "wrote BENCH_kernels.json ({} cells, kernels {gm_mt:.2}x/{gm_st:.2}x, \
         tile {gt_mt:.2}x/{gt_st:.2}x)",
        rows.len()
    );
    // The optimized backend must never lose to the naive kernels.
    assert!(
        gm_mt > 1.0 && gt_mt > 1.0,
        "optimized backend slower than naive (kernels {gm_mt:.2}x, tile {gt_mt:.2}x)"
    );
    // Acceptance floors, enforced on demand (CI machines under load
    // shouldn't flake the default run): >= 3x multi-thread geomean,
    // >= 1.5x single-thread (blocked+CSR alone).
    if std::env::var("GA_BENCH_STRICT").as_deref() == Ok("1") {
        assert!(gm_mt >= 3.0, "kernels geomean {gm_mt:.2}x below the 3x floor");
        assert!(gm_st >= 1.5, "single-thread kernels geomean {gm_st:.2}x below 1.5x");
    }
}
