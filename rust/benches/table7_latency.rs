//! Regenerates paper Table 7: T_E2E / T_LoC / T_LoH for b1-b8 x the
//! seven benchmark graphs.
use graphagile::harness::bench_support::run_bench;
use graphagile::harness::tables;
use graphagile::ir::ALL_MODELS;

fn main() {
    run_bench("table7_latency", |ctx, datasets| {
        let rows = tables::table7_rows(ctx, &ALL_MODELS, datasets);
        let cells: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    r.model.into(),
                    r.dataset.into(),
                    format!("{:.3}", r.t_e2e * 1e3),
                    format!("{:.3}", r.t_loc * 1e3),
                    format!("{:.3}", r.t_comm * 1e3),
                    format!("{:.3}", r.t_loh * 1e3),
                ]
            })
            .collect();
        graphagile::harness::markdown(
            &["Model", "Dataset", "T_E2E (ms)", "T_LoC (ms)", "T_comm (ms)", "T_LoH (ms)"],
            &cells,
        )
    });
}
