//! Mini-batch serving benchmark: a 1k-request ego-network trace over a
//! deterministic R-MAT graph, served through the shape-bucketed
//! program cache with micro-batched dispatch, against the same trace
//! served as whole-graph requests. Written to `BENCH_minibatch.json`
//! so the mini-batch perf trajectory is recorded across commits.
//! Everything runs on the virtual clock — the numbers are bit-identical
//! between runs, so a diff of the JSON is a real regression.
//!
//! Knobs: `GA_REQUESTS` (default 1000). `GA_BENCH_STRICT=1` enforces
//! the acceptance floors (bucket hit rate >= 90%, mini-batch p50 below
//! whole-graph p50); leave it unset on loaded machines.

use graphagile::config::HwConfig;
use graphagile::graph::Dataset;
use graphagile::ir::ZooModel;
use graphagile::serve::{Coordinator, FleetConfig, Request, ServeStats};
use graphagile::util::Rng;

/// The trace graph: a mid-size R-MAT synthetic (32k vertices) — big
/// enough that whole-graph inference visibly dwarfs an ego-net, small
/// enough to materialize and sample a thousand times in CI.
const RMAT_TRACE: Dataset = Dataset {
    key: "RM",
    name: "R-MAT-trace",
    n_vertices: 32_768,
    n_edges: 262_144,
    feat_len: 64,
    n_classes: 8,
    locality: 0.4,
};

const MODELS: [ZooModel; 4] = [ZooModel::B1, ZooModel::B2, ZooModel::B6, ZooModel::B7];

/// Request spacing: generous enough that the mini-batch run is not
/// queue-bound (its p50 then reflects per-request cost, which is the
/// property the floor checks).
const SPACING_S: f64 = 1e-3;

fn minibatch_trace(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let k = 1 + rng.below(2) as usize;
            let targets: Vec<u32> =
                (0..k).map(|_| rng.below(RMAT_TRACE.n_vertices) as u32).collect();
            Request::minibatch(
                rng.below(8) as u32,
                MODELS[rng.below(4) as usize],
                RMAT_TRACE,
                targets,
                vec![15, 10],
                seed ^ i as u64,
                i as f64 * SPACING_S,
            )
        })
        .collect()
}

fn fullgraph_trace(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            Request::full(
                rng.below(8) as u32,
                MODELS[rng.below(4) as usize],
                RMAT_TRACE,
                i as f64 * SPACING_S,
            )
        })
        .collect()
}

fn serve(reqs: Vec<Request>) -> (ServeStats, Coordinator) {
    let cfg = FleetConfig { n_devices: 2, ..FleetConfig::default() };
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
    let stats = c.run(reqs);
    (stats, c)
}

fn main() {
    let n: usize = std::env::var("GA_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let (mini, c) = serve(minibatch_trace(n, 11));
    let (full, _) = serve(fullgraph_trace(n, 11));
    let hit_rate = mini.bucket_hits as f64 / mini.minibatched.max(1) as f64;
    let buckets: usize = c.devices().iter().map(|d| d.cache_len()).sum();
    println!(
        "{:>10} {:>10} {:>10} {:>12} {:>10} {:>10}",
        "class", "p50 (ms)", "p99 (ms)", "hit rate", "batched", "programs"
    );
    println!(
        "{:>10} {:>10.4} {:>10.4} {:>12.4} {:>10} {:>10}",
        "mini", mini.p50 * 1e3, mini.p99 * 1e3, hit_rate, mini.batched, buckets
    );
    println!(
        "{:>10} {:>10.4} {:>10.4} {:>12} {:>10} {:>10}",
        "full", full.p50 * 1e3, full.p99 * 1e3, "-", "-", "-"
    );
    println!(
        "sampled {} vertices / {} edges across {} requests \
         (avg {:.1} vertices per ego-net)",
        mini.sampled_vertices,
        mini.sampled_edges,
        mini.minibatched,
        mini.sampled_vertices as f64 / mini.minibatched.max(1) as f64,
    );
    let json = format!(
        "{{\n  \"bench\": \"minibatch_serve\",\n  \"requests\": {n},\n  \
         \"graph\": {{\"vertices\": {}, \"edges\": {}, \"feat\": {}}},\n  \
         \"bucket_hit_rate\": {hit_rate:.4},\n  \"buckets_compiled\": {buckets},\n  \
         \"batched_riders\": {},\n  \"sampled_vertices\": {},\n  \
         \"sampled_edges\": {},\n  \"p50_mini_ms\": {:.4},\n  \
         \"p99_mini_ms\": {:.4},\n  \"p50_full_ms\": {:.4},\n  \
         \"p99_full_ms\": {:.4},\n  \"mini_makespan_s\": {:.6},\n  \
         \"full_makespan_s\": {:.6},\n  \
         \"floors\": {{\"bucket_hit_rate\": 0.90, \"p50_mini_below_full\": true}}\n}}\n",
        RMAT_TRACE.n_vertices,
        RMAT_TRACE.n_edges,
        RMAT_TRACE.feat_len,
        mini.batched,
        mini.sampled_vertices,
        mini.sampled_edges,
        mini.p50 * 1e3,
        mini.p99 * 1e3,
        full.p50 * 1e3,
        full.p99 * 1e3,
        mini.makespan,
        full.makespan,
    );
    std::fs::write("BENCH_minibatch.json", &json).expect("write BENCH_minibatch.json");
    eprintln!(
        "wrote BENCH_minibatch.json ({n} requests, hit rate {hit_rate:.3}, \
         p50 mini {:.3} ms vs full {:.3} ms)",
        mini.p50 * 1e3,
        full.p50 * 1e3
    );
    // Sanity that holds on any machine (virtual clock: deterministic).
    assert_eq!(mini.minibatched, n as u64);
    assert!(mini.sampled_edges > 0);
    // Acceptance floors, enforced on demand (the main-branch CI job
    // sets GA_BENCH_STRICT=1): the bucket cache must absorb >= 90% of
    // a diverse 1k-request trace, and serving a sampled neighborhood
    // must beat serving the whole graph at the median.
    if std::env::var("GA_BENCH_STRICT").as_deref() == Ok("1") {
        assert!(
            hit_rate >= 0.90,
            "bucket hit rate {hit_rate:.3} below the 0.90 floor"
        );
        assert!(
            mini.p50 < full.p50,
            "mini-batch p50 {:.4} ms !< whole-graph p50 {:.4} ms",
            mini.p50 * 1e3,
            full.p50 * 1e3
        );
    }
}
