//! Static vs density-aware dynamic kernel mapping (Dynasparse-style)
//! across the model zoo x an R-MAT density grid, written to
//! `BENCH_dynsparse.json` so the dynamic-mapping trajectory is recorded
//! across commits. Everything runs on the deterministic cycle model —
//! the numbers are bit-identical between runs.
//!
//! The grid spans three densities of seeded R-MAT synthetics: a
//! Table-4-like sparse graph (re-mapping must never fire nor hurt), a
//! mid-density graph near the threshold band, and a 0.75-dense graph
//! where dense subshards must re-map to GEMM and win. The bench asserts
//! the acceptance property outright: dynamic is never slower than static
//! on any cell and strictly faster on at least one.

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::{rmat_tile_counts, GraphMeta};
use graphagile::ir::ALL_MODELS;
use graphagile::sim::{simulate, simulate_dynamic};

fn main() {
    let hw = HwConfig::alveo_u250();
    // (name, |V|, |E|, feature length, classes): tile densities ~0.001,
    // ~0.125 and ~0.75 — below, at, and far above the threshold band.
    let grid = [
        ("rmat-sparse", 4096u64, 16_384u64),
        ("rmat-mid", 1024, 131_072),
        ("rmat-dense", 256, 49_152),
    ];
    let mut rows = Vec::new();
    let mut strictly_faster = 0u32;
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>9} {:>8}",
        "model", "graph", "static (ms)", "dynamic (ms)", "speedup", "remaps"
    );
    for model in ALL_MODELS {
        for &(name, nv, ne) in &grid {
            let meta = GraphMeta::new(name, nv, ne, 64, 8);
            let tiles = rmat_tile_counts(&meta, Default::default(), 17, hw.n1() as u64);
            let ir = model.build(meta);
            let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
            let stat = simulate(&exe.program, &hw);
            let dynv = simulate_dynamic(&exe.program, &hw);
            assert!(
                dynv.cycles <= stat.cycles,
                "{}/{name}: dynamic {} cycles > static {}",
                model.key(),
                dynv.cycles,
                stat.cycles
            );
            if dynv.cycles < stat.cycles {
                strictly_faster += 1;
            }
            let speedup = stat.cycles as f64 / dynv.cycles.max(1) as f64;
            println!(
                "{:>6} {:>12} {:>12.4} {:>12.4} {:>8.3}x {:>8}",
                model.key(),
                name,
                stat.loh_ms(),
                dynv.loh_ms(),
                speedup,
                dynv.remaps
            );
            rows.push(format!(
                "    {{\"model\": \"{}\", \"graph\": \"{name}\", \"vertices\": {nv}, \
                 \"edges\": {ne}, \"static_ms\": {:.6}, \"dynamic_ms\": {:.6}, \
                 \"speedup\": {:.4}, \"remaps\": {}}}",
                model.key(),
                stat.loh_ms(),
                dynv.loh_ms(),
                speedup,
                dynv.remaps,
            ));
        }
    }
    assert!(
        strictly_faster > 0,
        "dynamic mapping must be strictly faster on at least one cell"
    );
    let json = format!(
        "{{\n  \"bench\": \"dynsparse\",\n  \"cells\": {},\n  \
         \"strictly_faster\": {strictly_faster},\n  \"grid\": [\n{}\n  ]\n}}\n",
        rows.len(),
        rows.join(",\n")
    );
    std::fs::write("BENCH_dynsparse.json", &json).expect("write BENCH_dynsparse.json");
    eprintln!(
        "wrote BENCH_dynsparse.json ({} cells, {strictly_faster} strictly faster)",
        rows.len()
    );
}
