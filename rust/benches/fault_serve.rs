//! Fault-tolerant serving benchmark: latency percentiles and loss
//! accounting for the same deterministic workload served fault-free,
//! under a single mid-run device crash, and under a seeded
//! crash-and-recover chaos plan. Written to `BENCH_fault.json` so the
//! resilience trajectory is recorded across commits; everything runs on
//! the virtual clock, so the numbers are bit-identical between runs.
//!
//! Strict gates (`GA_BENCH_STRICT=1`):
//!   * p99 under a 1-device crash stays within 3x the fault-free p99,
//!   * shed rate is exactly 0 at nominal load (a crash on an
//!     N >= 2 fleet degrades latency, never loses requests).
//!
//! Knobs: `GA_REQUESTS` (default 400).

use graphagile::config::HwConfig;
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::serve::{
    Coordinator, FaultEvent, FaultPlan, FleetConfig, Request, ServeStats,
};
use graphagile::util::Rng;

const DEVICES: usize = 2;
const SPACING_S: f64 = 2e-4;

fn workload(n: usize, seed: u64) -> Vec<Request> {
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B6, ZooModel::B7];
    let graphs = [
        dataset("CI").unwrap(),
        dataset("CO").unwrap(),
        dataset("PU").unwrap(),
    ];
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            Request::full(
                rng.below(8) as u32,
                models[rng.below(4) as usize],
                graphs[rng.below(3) as usize],
                i as f64 * SPACING_S,
            )
        })
        .collect()
}

fn serve(reqs: &[Request], plan: Option<FaultPlan>) -> ServeStats {
    let cfg = FleetConfig { n_devices: DEVICES, ..FleetConfig::default() };
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
    if let Some(p) = plan {
        c.set_fault_plan(p);
    }
    c.run(reqs.to_vec())
}

fn row(name: &str, s: &ServeStats) -> String {
    format!(
        "    {{\"scenario\": \"{name}\", \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \
         \"mean_ms\": {:.4}, \"completed\": {}, \"shed\": {}, \"degraded\": {}, \
         \"retries\": {}, \"rerouted\": {}, \"crashes\": {}, \"stalls\": {}, \
         \"corruptions\": {}, \"downtime_s\": {:.6}, \"makespan_s\": {:.6}}}",
        s.p50 * 1e3,
        s.p99 * 1e3,
        s.mean * 1e3,
        s.completed,
        s.shed,
        s.degraded,
        s.retries,
        s.rerouted,
        s.crashes,
        s.stalls,
        s.corruptions,
        s.downtime,
        s.makespan,
    )
}

fn main() {
    let n: usize = std::env::var("GA_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let strict = std::env::var("GA_BENCH_STRICT").ok().as_deref() == Some("1");
    let reqs = workload(n, 11);
    let span = n as f64 * SPACING_S;

    let free = serve(&reqs, None);
    let one_crash = serve(
        &reqs,
        Some(FaultPlan {
            seed: 1,
            events: vec![FaultEvent::DeviceCrash {
                device: 1,
                at: span * 0.4,
                recover_after: 2e-3,
            }],
        }),
    );
    let chaos = serve(&reqs, Some(FaultPlan::crash_and_recover(23, DEVICES, span)));

    println!(
        "{:>12} {:>10} {:>10} {:>6} {:>9} {:>8} {:>9} {:>9}",
        "scenario", "p50 (ms)", "p99 (ms)", "shed", "degraded", "retries", "crashes", "downtime"
    );
    for (name, s) in [("fault_free", &free), ("one_crash", &one_crash), ("chaos", &chaos)] {
        println!(
            "{:>12} {:>10.3} {:>10.3} {:>6} {:>9} {:>8} {:>9} {:>9.4}",
            name,
            s.p50 * 1e3,
            s.p99 * 1e3,
            s.shed,
            s.degraded,
            s.retries,
            s.crashes,
            s.downtime
        );
    }

    let json = format!(
        "{{\n  \"bench\": \"fault_serve\",\n  \"requests\": {n},\n  \"devices\": {DEVICES},\n  \"scenarios\": [\n{}\n  ]\n}}\n",
        [row("fault_free", &free), row("one_crash", &one_crash), row("chaos", &chaos)]
            .join(",\n")
    );
    std::fs::write("BENCH_fault.json", &json).expect("write BENCH_fault.json");
    eprintln!("wrote BENCH_fault.json ({n} requests, {DEVICES} devices)");

    // Accounting invariants hold strict or not: a crash on a multi-device
    // fleet must never lose an accepted request.
    assert_eq!(free.shed, 0, "fault-free serving must not shed");
    assert_eq!(
        one_crash.completed + one_crash.shed,
        n as u64,
        "every request must end completed, degraded, or shed"
    );

    if strict {
        assert_eq!(
            one_crash.shed, 0,
            "STRICT: a 1-device crash at nominal load shed {} request(s)",
            one_crash.shed
        );
        assert!(
            one_crash.p99 <= 3.0 * free.p99,
            "STRICT: p99 under a 1-device crash regressed past 3x fault-free \
             ({:.3} ms > 3 x {:.3} ms)",
            one_crash.p99 * 1e3,
            free.p99 * 1e3,
        );
        eprintln!(
            "STRICT gates passed: crash p99 {:.3} ms <= 3 x fault-free p99 {:.3} ms, 0 shed",
            one_crash.p99 * 1e3,
            free.p99 * 1e3
        );
    }
}
